//! Step-level continuous-batching scheduler — the DLM analogue of
//! continuous batching (cf. dLLM-Cache / FlashDLM serving, PAPERS.md).
//!
//! The legacy serving path ran each request to completion inside one HTTP
//! worker; concurrent requests interleaved only by blind [`EngineCell`]
//! mutex contention — no fairness, no preemption, no accounting of KV
//! residency. Here the scheduler owns every in-flight [`Session`] and
//! **K driver workers** each run the pick→step→book loop concurrently
//! (see [`Scheduler::spawn_workers`]): a picked session is removed from the
//! run queue for the duration of its step, so concurrent picks are disjoint
//! by construction, and with an [`EnginePool`] executor K steps execute
//! truly in parallel, one per engine replica:
//!
//! * [`policy`] — who gets the next quantum (round-robin baseline,
//!   shortest-remaining-steps, deadline-aware);
//! * [`kvpool`] — byte-budgeted admission control over phase-cache
//!   residency (reject, don't overcommit);
//! * [`kvstore`] — the tiered, handle-based segment store that owns every
//!   resident KV cache: above the soft limit, cold segments *spill* to a
//!   disk tier (rehydrated transparently at the next checkout) instead of
//!   being dropped, and with `prefix_share` enabled, identical refresh
//!   forwards across sessions resolve to ONE shared segment by content
//!   address;
//! * [`Ticket`] — completion handle the serving layer blocks on.
//!
//! With `max_batch > 1` each quantum **coalesces**: the driver drains up to
//! `max_batch` policy-ordered sessions whose step plans (see
//! `coordinator::plan`) share a forward bucket and executes them as one
//! batched engine call, applying and booking each lane individually —
//! cross-session hardware batching on top of step-level fairness, with
//! outputs byte-identical to solo stepping (property-tested per strategy).
//! The width itself is load-adaptive under `--batch-policy adaptive` (the
//! [`governor`] picks it per tick from queue depth and trailing
//! occupancy/waste), and with `--coalesce-waste-pct > 0` a candidate whose
//! bucket is a *sub-bucket* of the leader's joins by padding its plan up
//! (cross-bucket promotion; outputs are sliced back before `apply`, so
//! parity with solo still holds).
//!
//! Steps run with the scheduler's run-queue lock **released**, so
//! submission and introspection (`GET /sessions`) stay responsive while the
//! engine is busy. `tick()` is public and synchronous: tests drive the
//! scheduler deterministically without background threads — including from
//! several test threads at once, which is exactly the K-worker regime.
//!
//! Shutdown discipline: `shutdown()` marks the scheduler stopped, joins the
//! driver workers, **waits for mid-step sessions to land** (their booking
//! path observes the stop flag and fails their tickets instead of
//! re-queueing into a drained queue), then fails everything still queued.
//! Every ticket ever issued resolves.
//!
//! [`EngineCell`]: crate::runtime::EngineCell
//! [`EnginePool`]: crate::runtime::EnginePool

pub mod governor;
pub mod kvpool;
pub mod kvstore;
pub mod policy;

pub use governor::{BatchGovernor, BatchPolicy, CounterSnapshot, GovernorConfig};
pub use kvpool::{KvPool, PoolExhausted};
pub use kvstore::{KvCheckout, KvHandle, KvStore, KvStoreConfig, PrefixKey};
pub use policy::Policy;

use std::collections::VecDeque;
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::plan::{
    execute_plan, execute_plan_recoverable, ForwardKind, KvOut, Planned, Promotion,
    StepOutputs, StepPlan,
};
use crate::coordinator::{is_transient, GenRequest, GenResult, StepExec};
use crate::metrics::Metrics;
use crate::runtime::{buckets, Arch};
use crate::strategies::{self, Session, StepOutcome};
use crate::trace::{TraceMode, TraceRecorder};
use crate::util::stats::RateMeter;
use crate::util::threadpool::ThreadPool;

/// Trailing window for the `steps_per_second` gauge (recent throughput, not
/// a lifetime average — see [`RateMeter`]).
const STEP_RATE_WINDOW: Duration = Duration::from_secs(2);

/// Per-bucket forward-count key: the batched-executable *suffix* for a
/// dispatch (`b{B}_s{S}[_c{C}[_r{R}]]`), so a production `/metrics` dump
/// maps 1:1 onto the names `aot.py` lowers — the input to
/// `--prune-buckets`.
fn bucket_key(b: usize, bucket: (usize, usize, usize)) -> String {
    let (s, c, r) = bucket;
    let mut key = format!("b{b}_s{s}");
    if c > 0 {
        key.push_str(&format!("_c{c}"));
    }
    if r > 0 {
        key.push_str(&format!("_r{r}"));
    }
    key
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub policy: Policy,
    /// KV pool byte budget (admission control); 0 = unlimited.
    pub kv_budget_bytes: usize,
    /// Hot-tier soft limit: above this, the [`KvStore`] spills cold
    /// (unpinned, least-recently-touched) segments to the disk tier; they
    /// rehydrate transparently at their next checkout. 0 = never spill.
    pub kv_soft_bytes: usize,
    /// Device-rung soft limit: above this, the [`KvStore`] demotes cold
    /// device-resident segments back to host-only (their host mirror stays
    /// hot, so demotion is free). 0 = uncapped. The rung only exists at all
    /// when the executor exposes a shared device (see
    /// `StepExec::device`).
    pub kv_device_soft_bytes: usize,
    /// Where spilled segments land; `None` = a per-store temp directory,
    /// removed when the scheduler drops.
    pub kv_spill_dir: Option<PathBuf>,
    /// Cross-session prefix sharing: content-address every Window (refresh)
    /// forward and let identical later plans skip the engine, attaching to
    /// the published segment instead. Off by default — sharing changes KV
    /// *residency* (one segment for N sessions), which soft-limit tests and
    /// byte-accounting consumers may not expect.
    pub prefix_share: bool,
    /// In-flight session cap; 0 = unlimited.
    pub max_sessions: usize,
    /// Coalescing width: each `tick` drains up to this many policy-ordered
    /// sessions whose plans share a forward bucket and executes them as ONE
    /// engine call (`StepExec::execute_batch`). 1 (or 0) = solo stepping.
    /// Under [`BatchPolicy::Adaptive`] this is the *ceiling*; the
    /// [`BatchGovernor`] picks the per-tick width underneath it.
    pub max_batch: usize,
    /// How the per-tick width is chosen: `Fixed` always uses `max_batch`
    /// (the PR-3 behavior); `Adaptive` lets the governor move along the
    /// executor's `b_ladder` with load.
    pub batch_policy: BatchPolicy,
    /// Cross-bucket coalescing ceiling: a candidate whose plan is a
    /// sub-bucket of the leader's may pad up ("promote") into the leader's
    /// bucket when the extra padded positions stay within this percentage
    /// of the leader bucket's total positions. 0 disables promotion
    /// (exact-bucket coalescing only — the PR-3 behavior).
    pub coalesce_waste_pct: usize,
    /// Step-lifecycle tracing (`serve --trace {off,ring}`). `Off` (the
    /// default) holds no recorder and adds no timestamp reads to the step
    /// path; `Ring` records spans into a bounded ring (`GET /trace`) and
    /// feeds the per-stage latency histograms on `GET /metrics`.
    pub trace: TraceMode,
    /// Transient-fault retry budget per session *streak*: a failed forward
    /// classified transient (see [`crate::coordinator::is_transient`])
    /// cancels the plan — restoring decode state and KV handles — and
    /// re-queues the session for up to this many consecutive attempts; any
    /// successful step resets the streak. 0 disables retries (every forward
    /// failure fails the ticket — the pre-fault-tolerance behavior).
    pub max_step_retries: u32,
    /// Pause before a retried session is eligible to be picked again — the
    /// injectable clock for retry pacing. `Duration::ZERO` retries
    /// immediately (what deterministic tests want: a manual
    /// `while tick().is_some()` drain never observes an empty-but-backing-
    /// off queue).
    pub retry_backoff: Duration,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            policy: Policy::RoundRobin,
            kv_budget_bytes: 0,
            kv_soft_bytes: 0,
            kv_device_soft_bytes: 0,
            kv_spill_dir: None,
            prefix_share: false,
            max_sessions: 64,
            max_batch: 1,
            batch_policy: BatchPolicy::Fixed,
            coalesce_waste_pct: 0,
            trace: TraceMode::Off,
            max_step_retries: 3,
            retry_backoff: Duration::from_millis(5),
        }
    }
}

/// One generation to schedule.
pub struct SubmitSpec {
    /// Strategy spec (see `strategies::from_name`).
    pub strategy: String,
    pub req: GenRequest,
    /// Latency target for the deadline policy (relative to submission).
    pub deadline: Option<Duration>,
}

/// Why a submission was refused. `Pool` and `Saturated` are backpressure
/// (HTTP 429); `Start` is a bad request or engine failure.
pub enum SubmitError {
    Pool(PoolExhausted),
    Saturated { active: usize, max: usize },
    Start(anyhow::Error),
}

impl SubmitError {
    pub fn is_backpressure(&self) -> bool {
        matches!(self, SubmitError::Pool(_) | SubmitError::Saturated { .. })
    }
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Pool(p) => write!(f, "{p}"),
            SubmitError::Saturated { active, max } => {
                write!(f, "scheduler saturated: {active}/{max} sessions in flight")
            }
            SubmitError::Start(e) => write!(f, "{e}"),
        }
    }
}

impl fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

/// Completion handle: fulfilled by the scheduler when the session finishes
/// (or fails, or the scheduler shuts down).
pub struct Ticket {
    pub id: u64,
    inner: Arc<TicketInner>,
}

struct TicketInner {
    slot: Mutex<Option<Result<GenResult>>>,
    cv: Condvar,
}

impl TicketInner {
    fn fulfill(&self, r: Result<GenResult>) {
        let mut slot = self.slot.lock().unwrap();
        *slot = Some(r);
        self.cv.notify_all();
    }
}

impl Ticket {
    /// Block until the session completes. Bounded in practice by the
    /// request's step cap — every session terminates, errors, or is failed
    /// by shutdown.
    pub fn wait(self) -> Result<GenResult> {
        let mut slot = self.inner.slot.lock().unwrap();
        loop {
            if let Some(r) = slot.take() {
                return r;
            }
            slot = self.inner.cv.wait(slot).unwrap();
        }
    }

    pub fn is_ready(&self) -> bool {
        self.inner.slot.lock().unwrap().is_some()
    }
}

/// Introspection row for `GET /sessions`.
#[derive(Debug, Clone)]
pub struct SessionInfo {
    pub id: u64,
    pub strategy: String,
    pub steps: usize,
    pub remaining: usize,
    pub gen_len: usize,
    pub age_secs: f64,
    /// Accumulated engine time (ms). `age_secs * 1000 - busy_ms` is the
    /// session's queue time — the fairness-vs-load signal per session.
    pub busy_ms: f64,
    pub kv_bytes: usize,
    pub deadline_in_secs: Option<f64>,
    /// Accumulated run-queue wait (ms), including time in the queue right
    /// now — from the trace recorder; `None` under `--trace off`.
    pub queue_ms: Option<f64>,
    /// Admit → first committed token (ms); `None` until the first token
    /// lands or under `--trace off`.
    pub ttft_ms: Option<f64>,
}

struct Active {
    id: u64,
    seq: u64,
    session: Session,
    ticket: Arc<TicketInner>,
    deadline: Option<Instant>,
    /// Quantum counter at the session's last step (LRU for eviction).
    last_stepped: u64,
    /// Consecutive transient-failure retries; reset by any successful step.
    attempts: u32,
    /// While set (and in the future), the session is invisible to
    /// `pick_active` — the retry pacing clock.
    backoff_until: Option<Instant>,
}

struct Inner {
    run: VecDeque<Active>,
    /// Sessions currently out of `run` being stepped (lock released). They
    /// still count toward `max_sessions` and the active-sessions gauge, and
    /// are invisible to `policy::pick` — concurrent drivers always step
    /// disjoint sessions.
    stepping: usize,
    /// Submissions past the admission checks but still building their
    /// session (lock released); they hold a pool reservation and count
    /// toward `max_sessions`.
    admitting: usize,
    pool: KvPool,
    quantum: u64,
    /// Steps-per-second over a trailing window (not a lifetime average).
    rate: RateMeter,
    /// Engine dispatches over the same window — with `lane_rate`, the
    /// `batch_occupancy_recent` gauge (lanes per forward, recent only).
    fwd_rate: RateMeter,
    lane_rate: RateMeter,
    /// KV bytes freed over a trailing window (completed sessions' released
    /// reservations + hot-tier bytes freed by spills) — the denominator of
    /// the 429 `retry_after_ms` hint.
    free_rate: RateMeter,
}

pub struct Scheduler {
    exec: Arc<dyn StepExec + Send + Sync>,
    /// Executor batch-lane ladder, snapshotted at construction (waste
    /// accounting for whole-lane padding; never contends with steps).
    b_ladder: Vec<usize>,
    /// Architecture snapshot (promoted-lane output demotion needs vocab and
    /// KV dims; never contends with steps).
    arch: Arch,
    /// Present under `BatchPolicy::Adaptive`: the per-tick width decision.
    governor: Option<Mutex<BatchGovernor>>,
    /// Deadline-pressure horizon copied from the governor's config: queued
    /// sessions due within this of "now" count as urgent and narrow the
    /// tick (EDF policy + adaptive width only).
    deadline_slack: Duration,
    cfg: SchedulerConfig,
    /// The tiered KV segment store shared by every session this scheduler
    /// admits (sessions are re-pointed at it in `submit`, before their
    /// first segment exists).
    store: Arc<KvStore>,
    inner: Mutex<Inner>,
    work: Condvar,
    /// Signalled when `stepping` drops to zero while stopping — `shutdown`
    /// waits on it so mid-step sessions land before the queue is drained.
    quiesce: Condvar,
    stop: AtomicBool,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    steps_total: AtomicU64,
    drivers: Mutex<Option<ThreadPool>>,
    /// Present under `--trace ring`; `None` is the zero-overhead off mode
    /// (every record site is gated on this Option, including its
    /// `Instant::now()` reads).
    trace: Option<Arc<TraceRecorder>>,
}

impl Scheduler {
    pub fn new(exec: Arc<dyn StepExec + Send + Sync>, cfg: SchedulerConfig,
               metrics: Arc<Metrics>) -> Arc<Scheduler> {
        let pool = KvPool::new(cfg.kv_budget_bytes);
        let b_ladder = exec.b_ladder();
        let arch = exec.arch();
        let mut deadline_slack = Duration::ZERO;
        let governor = match cfg.batch_policy {
            BatchPolicy::Fixed => None,
            BatchPolicy::Adaptive => {
                let mut gcfg = GovernorConfig::new(b_ladder.clone(), cfg.max_batch.max(1));
                gcfg.waste_ceiling_pct = cfg.coalesce_waste_pct;
                deadline_slack = gcfg.deadline_slack;
                Some(Mutex::new(BatchGovernor::new(gcfg)))
            }
        };
        metrics.batch_width.store(
            match cfg.batch_policy {
                BatchPolicy::Fixed => cfg.max_batch.max(1) as u64,
                BatchPolicy::Adaptive => 1,
            },
            Ordering::Relaxed,
        );
        let t0 = Instant::now();
        let trace = match cfg.trace {
            TraceMode::Off => None,
            TraceMode::Ring => Some(Arc::new(TraceRecorder::new())),
        };
        let store = KvStore::new(KvStoreConfig {
            soft_bytes: cfg.kv_soft_bytes,
            device_soft_bytes: cfg.kv_device_soft_bytes,
            spill_dir: cfg.kv_spill_dir.clone(),
        });
        if let Some(tr) = &trace {
            store.attach_trace(Arc::clone(tr));
        }
        // Device hot tier: when the executor runs on one shared device,
        // the store can keep segments resident there and checkouts skip
        // the per-step re-upload. Copy-mode pools (and plain mocks) expose
        // no device, leaving the store host-only.
        if let Some(dev) = exec.device() {
            store.attach_device(dev);
        }
        Arc::new(Scheduler {
            exec,
            b_ladder,
            arch,
            governor,
            deadline_slack,
            cfg,
            store,
            inner: Mutex::new(Inner {
                run: VecDeque::new(),
                stepping: 0,
                admitting: 0,
                pool,
                quantum: 0,
                rate: RateMeter::new(STEP_RATE_WINDOW, t0),
                fwd_rate: RateMeter::new(STEP_RATE_WINDOW, t0),
                lane_rate: RateMeter::new(STEP_RATE_WINDOW, t0),
                free_rate: RateMeter::new(STEP_RATE_WINDOW, t0),
            }),
            work: Condvar::new(),
            quiesce: Condvar::new(),
            stop: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            metrics,
            steps_total: AtomicU64::new(0),
            drivers: Mutex::new(None),
            trace,
        })
    }

    pub fn policy(&self) -> Policy {
        self.cfg.policy
    }

    pub fn batch_policy(&self) -> BatchPolicy {
        self.cfg.batch_policy
    }

    /// The step-lifecycle trace recorder (`Some` under `--trace ring`) —
    /// the `/trace` and `/metrics` handlers read it.
    pub fn trace(&self) -> Option<&Arc<TraceRecorder>> {
        self.trace.as_ref()
    }

    /// The tiered KV segment store (`/info` and `/metrics` read its tier
    /// gauges; benches read its hit/spill counters).
    pub fn kv_store(&self) -> &Arc<KvStore> {
        &self.store
    }

    pub fn prefix_share_enabled(&self) -> bool {
        self.cfg.prefix_share
    }

    /// Admit a session. Admission checks (saturation, KV budget) run
    /// *before* the sequence state is built, so a saturated server refuses
    /// a request without paying per-request allocations — the refusal path
    /// is O(1). Backpressure errors map to HTTP 429.
    pub fn submit(&self, spec: SubmitSpec) -> Result<Ticket, SubmitError> {
        if self.stop.load(Ordering::Relaxed) {
            return Err(SubmitError::Start(anyhow!("scheduler is shut down")));
        }
        // cheap spec validation (no allocations proportional to the request)
        let strategy = strategies::from_name(&spec.strategy).map_err(SubmitError::Start)?;
        let est = KvPool::estimate_bytes(
            &self.exec.arch(),
            &self.exec.c_ladder(spec.req.s),
            spec.req.prompt.len() + spec.req.gen_len,
        );

        let id = {
            let mut inner = self.inner.lock().unwrap();
            if self.stop.load(Ordering::Relaxed) {
                return Err(SubmitError::Start(anyhow!("scheduler is shut down")));
            }
            let in_flight = inner.run.len() + inner.stepping + inner.admitting;
            if self.cfg.max_sessions > 0 && in_flight >= self.cfg.max_sessions {
                self.metrics.sched_rejections.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Saturated {
                    active: in_flight,
                    max: self.cfg.max_sessions,
                });
            }
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            if let Err(mut e) = inner.pool.try_reserve(id, est) {
                e.retry_after_ms = Some(self.retry_hint_ms(&inner, e.need));
                self.update_gauges(&mut inner);
                return Err(SubmitError::Pool(e));
            }
            // hold the slot (and the reservation) while the session is built
            // with the lock released
            inner.admitting += 1;
            id
        };

        let session = strategy.start(self.exec.as_ref(), &spec.req);

        let mut inner = self.inner.lock().unwrap();
        inner.admitting -= 1;
        let mut session = match session {
            Ok(s) => s,
            Err(e) => {
                self.release_metered(&mut inner, id);
                self.update_gauges(&mut inner);
                return Err(SubmitError::Start(e));
            }
        };
        // every admitted session shares THIS scheduler's segment store —
        // attached before its first step, so no segment ever lives in the
        // per-session detached default
        session.attach_kv_store(Arc::clone(&self.store));
        // re-check under the lock: shutdown() drains under this same lock,
        // so a session pushed here is either refused or guaranteed to be
        // drained — never stranded with an unfulfilled ticket
        if self.stop.load(Ordering::Relaxed) {
            self.release_metered(&mut inner, id);
            self.update_gauges(&mut inner);
            return Err(SubmitError::Start(anyhow!("scheduler is shut down")));
        }
        let ticket_inner = Arc::new(TicketInner {
            slot: Mutex::new(None),
            cv: Condvar::new(),
        });
        inner.run.push_back(Active {
            id,
            seq: id,
            session,
            ticket: Arc::clone(&ticket_inner),
            deadline: spec.deadline.map(|d| Instant::now() + d),
            last_stepped: 0,
            attempts: 0,
            backoff_until: None,
        });
        if let Some(tr) = &self.trace {
            tr.admit(id, Instant::now());
        }
        self.update_gauges(&mut inner);
        // notify while holding the lock: a driver cannot miss the wakeup
        self.work.notify_one();
        drop(inner);
        Ok(Ticket { id, inner: ticket_inner })
    }

    /// Release a session's pool reservation and feed the freed bytes into
    /// the trailing free-rate meter (the `retry_after_ms` denominator).
    fn release_metered(&self, inner: &mut Inner, id: u64) {
        let freed = inner.pool.release(id);
        if freed > 0 {
            inner.free_rate.note_n(Instant::now(), freed as u64);
        }
    }

    /// 429 backpressure hint: at the trailing byte free rate (releases +
    /// spills), how long until `need` bytes could plausibly be free? A
    /// conservative fixed fallback when nothing freed recently — the hint
    /// must exist precisely when the pool is wedged full.
    fn retry_hint_ms(&self, inner: &Inner, need: usize) -> u64 {
        const FALLBACK_MS: u64 = 100;
        let rate = inner.free_rate.rate(Instant::now()); // bytes/sec
        if rate > 0.0 {
            (((need as f64) / rate) * 1e3).ceil().clamp(1.0, 60_000.0) as u64
        } else {
            FALLBACK_MS
        }
    }

    /// Remove the policy's next session from the run queue. Sessions inside
    /// a retry backoff window are invisible to the policy until it expires
    /// — `None` when nothing is eligible *right now* (drivers re-poll on the
    /// run-loop wait timeout, so a backing-off queue is never stranded).
    fn pick_active(&self, inner: &mut Inner) -> Option<Active> {
        if inner.run.is_empty() {
            return None;
        }
        let now = Instant::now();
        let mut eligible: Vec<usize> = Vec::with_capacity(inner.run.len());
        let mut views: Vec<policy::PickView> = Vec::with_capacity(inner.run.len());
        for (i, a) in inner.run.iter().enumerate() {
            #[allow(clippy::unnecessary_map_or)] // Option::is_none_or needs Rust 1.82
            let ready = a.backoff_until.map_or(true, |t| t <= now);
            if ready {
                eligible.push(i);
                views.push(policy::PickView {
                    remaining: a.session.remaining(),
                    deadline: a.deadline,
                    seq: a.seq,
                });
            }
        }
        if views.is_empty() {
            return None;
        }
        let idx = policy::pick(self.cfg.policy, &views);
        inner.run.remove(eligible[idx])
    }

    /// Route one lane's failed forward: degrade, retry, or fail the ticket.
    ///
    /// * [`kvstore::SegmentLost`] — the session's cached segment is gone
    ///   from every tier (spill blob missing or corrupt), so retrying the
    ///   same plan can only fail again on any replica. Cancel the plan,
    ///   evict the dead cache, and re-queue: the session's next plan is a
    ///   refresh forward that recomputes the segment. Degradation never
    ///   burns a retry attempt.
    /// * Transient (replica fault, all replicas quarantined) within budget —
    ///   cancel the plan (restoring decode state and KV handles) and
    ///   re-queue behind the backoff window. The pool rotates a failed
    ///   replica to the bottom of its idle stack, so the retry lands on a
    ///   different replica whenever one exists.
    /// * Transient with the budget exhausted — fail the ticket with an
    ///   error that names the retry count, distinguishing
    ///   transient-exhausted from fatal.
    /// * Anything else is fatal and passes through unchanged.
    fn route_failure(&self, active: &mut Active, plan: StepPlan,
                     e: anyhow::Error) -> Result<StepOutcome> {
        let now = Instant::now();
        if kvstore::is_segment_lost(&e) {
            active.session.cancel_plan(plan);
            active.session.evict_cache();
            self.metrics.degraded_recomputes.fetch_add(1, Ordering::Relaxed);
            if let Some(tr) = &self.trace {
                tr.degrade(active.id, now);
            }
            return Ok(StepOutcome::Running);
        }
        if is_transient(&e) && active.attempts < self.cfg.max_step_retries {
            active.session.cancel_plan(plan);
            active.attempts += 1;
            if !self.cfg.retry_backoff.is_zero() {
                active.backoff_until = Some(now + self.cfg.retry_backoff);
            }
            self.metrics.step_retries.fetch_add(1, Ordering::Relaxed);
            if let Some(tr) = &self.trace {
                tr.retry(active.id, active.attempts, now);
            }
            return Ok(StepOutcome::Running);
        }
        if is_transient(&e) {
            self.metrics.step_retries_exhausted.fetch_add(1, Ordering::Relaxed);
            return Err(e.context(format!(
                "transient fault persisted after {} retry attempts",
                active.attempts
            )));
        }
        Err(e)
    }

    /// Book one session's quantum outcome under the run-queue lock (shared
    /// by the solo, batched and plan-time-error paths).
    fn book(&self, inner: &mut Inner, active: Active, outcome: Result<StepOutcome>) {
        let id = active.id;
        match outcome {
            Ok(StepOutcome::Running) => {
                if self.stop.load(Ordering::Relaxed) {
                    // shutdown raced this step: the run queue is (being)
                    // drained, so re-queueing would strand the ticket in a
                    // dead queue — fail it instead
                    self.release_metered(inner, id);
                    self.metrics.record_request(Duration::ZERO, 0, 0, false);
                    if let Some(tr) = &self.trace {
                        tr.finished(id);
                    }
                    active.ticket.fulfill(Err(anyhow!(
                        "scheduler shut down mid-generation"
                    )));
                } else {
                    if let Some(tr) = &self.trace {
                        tr.requeued(id, Instant::now());
                    }
                    inner.run.push_back(active);
                    // another driver may be parked with an empty queue
                    self.work.notify_one();
                }
            }
            Ok(StepOutcome::Finished) => {
                self.release_metered(inner, id);
                if let Some(tr) = &self.trace {
                    tr.finished(id);
                }
                let Active { session, ticket, .. } = active;
                let result = session.into_result();
                self.metrics.record_request(
                    result.wall,
                    result.tokens_generated(),
                    result.steps,
                    true,
                );
                ticket.fulfill(Ok(result));
            }
            Err(e) => {
                self.release_metered(inner, id);
                self.metrics.record_request(Duration::ZERO, 0, 0, false);
                if let Some(tr) = &self.trace {
                    tr.finished(id);
                }
                active.ticket.fulfill(Err(e));
            }
        }
    }

    /// Apply a step's outputs, recording the apply span and — when newly
    /// decoded positions landed — a commit event (the first commit closes
    /// the session's TTFT window). A plain `Session::apply` under
    /// `--trace off`.
    fn apply_traced(&self, active: &mut Active, out: StepOutputs) -> Result<StepOutcome> {
        let Some(tr) = &self.trace else {
            return active.session.apply(out);
        };
        let rem_before = active.session.remaining();
        let a0 = Instant::now();
        let r = active.session.apply(out);
        let now = Instant::now();
        tr.apply(active.id, a0, now);
        let rem_after = active.session.remaining();
        if rem_after < rem_before {
            tr.commit(active.id, (rem_before - rem_after) as u32, now);
        }
        r
    }

    /// Book one per-kind forward into the metrics counters. `b` is the
    /// dispatched lane bucket (the `b_ladder` rung the lane count rounded
    /// up to; 1 for solo) — together with the plan's `(s, c, r)` bucket it
    /// keys the per-bucket forward counts that `aot.py --prune-buckets`
    /// consumes.
    fn note_forward(&self, kind: ForwardKind, lanes: usize, used: usize, padded: usize,
                    b: usize, bucket: (usize, usize, usize)) {
        let counters = match kind {
            ForwardKind::Full => &self.metrics.fwd_full,
            ForwardKind::Window => &self.metrics.fwd_window,
            ForwardKind::Cached => &self.metrics.fwd_cached,
        };
        counters.note(lanes, used, padded);
        // per-bucket dispatch counts exist to drive `--prune-buckets`, which
        // only ever prunes batched (B > 1) combos — solo dispatches skip the
        // map so the hot solo path stays free of the lock + key allocation
        if b > 1 {
            counters.note_bucket(bucket_key(b, bucket));
        }
    }

    /// Cross-bucket admission rule: `candidate` may pad up into `leader`'s
    /// bucket iff promotion is enabled (`coalesce_waste_pct > 0`), the
    /// candidate is a strict sub-bucket, and the extra padded positions stay
    /// within the configured percentage of the leader bucket's total
    /// positions — so padding can never exceed the (bounded) win of sharing
    /// one forward.
    fn promotion_admissible(&self, candidate: &StepPlan, leader: &StepPlan) -> bool {
        if self.cfg.coalesce_waste_pct == 0 {
            return false;
        }
        match candidate.promote_cost_into(leader) {
            Some(extra) if extra > 0 => {
                let total = buckets::bucket_positions(leader.bucket());
                extra * 100 <= self.cfg.coalesce_waste_pct * total
            }
            _ => false,
        }
    }

    /// Advance one quantum. In solo mode (width 1) this is the classic
    /// pick→step→book loop: planning, the forward and apply all run with
    /// the run-queue lock released, exactly like the pre-protocol
    /// `Session::step` path. In coalescing mode the quantum additionally
    /// drains bucket-compatible (or promotable) followers — see
    /// [`Scheduler::tick_coalesced`].
    ///
    /// The width is `max_batch` under [`BatchPolicy::Fixed`]; under
    /// [`BatchPolicy::Adaptive`] the [`BatchGovernor`] picks it per tick
    /// from queue depth and the trailing occupancy/waste counters — a
    /// short queue degrades to solo ticks, which keeps planning off the
    /// run-queue lock exactly when latency matters most.
    ///
    /// Safe to call from several threads at once — picked sessions leave
    /// the run queue for the duration of their step, so concurrent ticks
    /// always step disjoint sessions. Returns the stepped (leader)
    /// session's id, or `None` when nothing is runnable *right now* (other
    /// sessions may still be mid-step on other threads).
    pub fn tick(&self) -> Option<u64> {
        let width = match &self.governor {
            None => self.cfg.max_batch.max(1),
            Some(g) => {
                // urgent = queued sessions due within the deadline slack
                // (EDF only — other policies don't track deadlines): the
                // governor trades the depth target for the smallest rung
                // that still seats them (ROADMAP "governor-driven deadline
                // awareness")
                let (depth, urgent) = {
                    let inner = self.inner.lock().unwrap();
                    let depth = inner.run.len();
                    let mut urgent = 0usize;
                    if self.cfg.policy == Policy::Deadline {
                        // the EDF picker already walks the whole queue
                        // under this lock every tick, so counting here
                        // adds no new complexity class — and the count
                        // stops early once it saturates the ladder
                        // (rung_at_least is constant beyond max_batch)
                        let horizon = Instant::now() + self.deadline_slack;
                        let cap = self.cfg.max_batch.max(1);
                        for a in inner.run.iter() {
                            if a.deadline.is_some_and(|d| d <= horizon) {
                                urgent += 1;
                                if urgent >= cap {
                                    break;
                                }
                            }
                        }
                    }
                    (depth, urgent)
                };
                let snap = CounterSnapshot::of(&self.metrics);
                let w = {
                    let mut gov = g.lock().unwrap();
                    let w = gov.decide_deadline(Instant::now(), depth, urgent, snap);
                    if let Some(tr) = &self.trace {
                        if let Some((from, to)) = gov.take_transition() {
                            tr.width_change(from, to, Instant::now());
                        }
                    }
                    w
                };
                self.metrics.batch_width.store(w as u64, Ordering::Relaxed);
                w
            }
        };
        if width <= 1 {
            self.tick_solo()
        } else {
            self.tick_coalesced(width)
        }
    }

    /// Solo quantum: the run-queue lock is held only to pick and to book —
    /// planning CPU (layout rebuilds, tensor assembly) does not serialize
    /// against other drivers, submission or `GET /sessions`.
    fn tick_solo(&self) -> Option<u64> {
        let mut inner = self.inner.lock().unwrap();
        let mut active = self.pick_active(&mut inner)?;
        let id = active.id;
        inner.stepping += 1;
        inner.quantum += 1;
        active.last_stepped = inner.quantum;
        if let Some(tr) = &self.trace {
            tr.picked(id, Instant::now());
        }
        drop(inner);

        let mut stepped = false;
        let plan_start = self.trace.as_ref().map(|_| Instant::now());
        let planned = active.session.plan();
        if let (Some(tr), Some(p0)) = (&self.trace, plan_start) {
            tr.plan(id, p0, Instant::now());
        }
        let outcome = match planned {
            // zero-work session (gen_len == 0): finished without an engine call
            Ok(Planned::Finished) => Ok(StepOutcome::Finished),
            Ok(Planned::Forward(plan)) => {
                stepped = true;
                let kind = plan.kind();
                // cross-session prefix reuse: a Window plan whose content
                // address matches a published forward skips the engine call
                // entirely — the shared logits plus a fresh handle on the
                // SAME segment stand in for it, byte-identical by
                // construction (the key covers every forward input)
                let key = if self.cfg.prefix_share { Self::prefix_key(&plan) } else { None };
                match key.as_ref().and_then(|k| self.store.prefix_lookup(k)) {
                    Some((logits, handle)) => {
                        active.attempts = 0;
                        active.backoff_until = None;
                        let out =
                            StepOutputs::LogitsKv((*logits).clone(), KvOut::Shared(handle));
                        self.apply_traced(&mut active, out)
                    }
                    None => {
                        self.note_forward(
                            kind,
                            1,
                            plan.used_positions(),
                            plan.padded_positions(),
                            1,
                            plan.bucket(),
                        );
                        let t0 = Instant::now();
                        let res = execute_plan_recoverable(self.exec.as_ref(), plan);
                        active.session.add_busy(t0.elapsed());
                        if let Some(tr) = &self.trace {
                            tr.forward(kind, id, 1, t0, Instant::now());
                        }
                        match res {
                            Ok(out) => {
                                active.attempts = 0;
                                active.backoff_until = None;
                                let out = self.maybe_publish(key, out);
                                self.apply_traced(&mut active, out)
                            }
                            // the failed forward hands the plan back intact,
                            // so degrade/retry can restore the session
                            Err((plan, e)) => self.route_failure(&mut active, plan, e),
                        }
                    }
                }
            }
            Err(e) => Err(e),
        };
        if stepped {
            self.steps_total.fetch_add(1, Ordering::Relaxed);
        }

        let mut inner = self.inner.lock().unwrap();
        inner.stepping -= 1;
        if stepped {
            let now = Instant::now();
            inner.rate.note(now);
            inner.fwd_rate.note(now);
            inner.lane_rate.note(now);
        }
        self.book(&mut inner, active, outcome);
        self.update_gauges(&mut inner);
        if inner.stepping == 0 {
            // shutdown() may be waiting for mid-step sessions to land
            self.quiesce.notify_all();
        }
        Some(id)
    }

    /// Content address of a Window (refresh) plan; `None` for any other
    /// plan kind — only refresh forwards are pure functions of plan inputs
    /// alone (cached steps also depend on the incoming segment).
    fn prefix_key(plan: &StepPlan) -> Option<PrefixKey> {
        match plan {
            StepPlan::Window { s, c, ids, pos, valid } => {
                Some(PrefixKey::new(*s, *c, ids, pos, valid))
            }
            _ => None,
        }
    }

    /// After a keyed Window forward: adopt the fresh KV into the shared
    /// store, publish (key → logits + segment) for future sessions, and
    /// hand the session the resulting handle (`KvOut::Shared`) so it does
    /// not re-insert the same bytes. Falls back to the unshared output if
    /// the host transfer fails.
    fn maybe_publish(&self, key: Option<PrefixKey>, out: StepOutputs) -> StepOutputs {
        let Some(key) = key else { return out };
        match out {
            StepOutputs::LogitsKv(logits, KvOut::Fresh(kv)) => match self.store.insert(&kv) {
                Ok(handle) => {
                    self.store.publish(key, logits.clone(), &handle);
                    StepOutputs::LogitsKv(logits, KvOut::Shared(handle))
                }
                Err(_) => StepOutputs::LogitsKv(logits, KvOut::Fresh(kv)),
            },
            other => other,
        }
    }

    /// Coalesced quantum: pick a leader session per policy, plan it, and
    /// drain up to `max_batch - 1` further policy-ordered sessions whose
    /// plans share the leader's forward bucket — or, with a non-zero
    /// `coalesce_waste_pct`, whose plans are a *sub-bucket* of it: such a
    /// candidate pads its plan up to the leader's bucket
    /// (`StepPlan::promote_into`) and its outputs are sliced back to the
    /// original shape before `apply` (`Promotion::demote`), so the
    /// session's strategy state stays byte-identical to solo. The lanes
    /// execute as ONE engine call with the run-queue lock released
    /// (planning stays under the lock — it must inspect and mutate the
    /// queue to scan candidates; sessions whose plans don't match hand
    /// their plan back via `cancel_plan` and return to the queue front
    /// unstepped). Each lane is applied and booked individually, so
    /// per-session semantics (tickets, KV accounting, eviction, policy
    /// state) are identical to solo stepping — and so are the outputs, by
    /// the protocol's construction.
    fn tick_coalesced(&self, max_batch: usize) -> Option<u64> {
        let mut inner = self.inner.lock().unwrap();
        let mut leader = self.pick_active(&mut inner)?;
        let leader_id = leader.id;
        inner.quantum += 1;
        leader.last_stepped = inner.quantum;
        if let Some(tr) = &self.trace {
            tr.picked(leader_id, Instant::now());
        }
        let plan_start = self.trace.as_ref().map(|_| Instant::now());
        let leader_planned = leader.session.plan();
        if let (Some(tr), Some(p0)) = (&self.trace, plan_start) {
            tr.plan(leader_id, p0, Instant::now());
        }
        let leader_plan = match leader_planned {
            Ok(Planned::Forward(p)) => p,
            Ok(Planned::Finished) => {
                // zero-work session (gen_len == 0): book without an engine call
                self.book(&mut inner, leader, Ok(StepOutcome::Finished));
                self.update_gauges(&mut inner);
                return Some(leader_id);
            }
            Err(e) => {
                self.book(&mut inner, leader, Err(e));
                self.update_gauges(&mut inner);
                return Some(leader_id);
            }
        };

        // -- coalesce compatible followers (policy order preserved) -----------
        let mut lanes: Vec<(Active, StepPlan, Option<Promotion>)> =
            vec![(leader, leader_plan, None)];
        let scan_start = self.trace.as_ref().map(|_| Instant::now());
        if max_batch > 1 {
            let mut skipped: Vec<Active> = Vec::new();
            // bound the scan: a heterogeneous queue must not make one tick
            // plan/cancel every session while holding the run-queue lock
            // (submission and /sessions block on it); beyond this many
            // mismatches the remaining queue is unlikely to fill the batch
            let max_mismatches = 2 * max_batch;
            while lanes.len() < max_batch && skipped.len() < max_mismatches {
                let Some(mut cand) = self.pick_active(&mut inner) else { break };
                let cand_id = cand.id;
                if let Some(tr) = &self.trace {
                    tr.picked(cand_id, Instant::now());
                }
                let cand_plan_start = self.trace.as_ref().map(|_| Instant::now());
                let cand_planned = cand.session.plan();
                if let (Some(tr), Some(p0)) = (&self.trace, cand_plan_start) {
                    tr.plan(cand_id, p0, Instant::now());
                }
                match cand_planned {
                    Ok(Planned::Forward(p)) if p.compatible(&lanes[0].1) => {
                        inner.quantum += 1;
                        cand.last_stepped = inner.quantum;
                        lanes.push((cand, p, None));
                    }
                    Ok(Planned::Forward(p)) => {
                        // bucket mismatch: a sub-bucket plan may still join
                        // by padding up to the leader's bucket, if the
                        // extra padding stays under the waste ceiling;
                        // otherwise hand the plan back, unstepped
                        if self.promotion_admissible(&p, &lanes[0].1) {
                            match p.promote_into(&lanes[0].1, &self.arch) {
                                Ok((promoted, promo)) => {
                                    inner.quantum += 1;
                                    cand.last_stepped = inner.quantum;
                                    self.metrics
                                        .promoted_lanes
                                        .fetch_add(1, Ordering::Relaxed);
                                    self.metrics.promoted_padded_slots.fetch_add(
                                        promo.extra_positions as u64,
                                        Ordering::Relaxed,
                                    );
                                    lanes.push((cand, promoted, Some(promo)));
                                }
                                Err(original) => {
                                    cand.session.cancel_plan(*original);
                                    skipped.push(cand);
                                }
                            }
                        } else {
                            cand.session.cancel_plan(p);
                            skipped.push(cand);
                        }
                    }
                    Ok(Planned::Finished) => {
                        self.book(&mut inner, cand, Ok(StepOutcome::Finished));
                    }
                    Err(e) => {
                        self.book(&mut inner, cand, Err(e));
                    }
                }
            }
            // skipped sessions return to the queue FRONT in pick order, so
            // their policy position is unchanged for the next tick
            for a in skipped.into_iter().rev() {
                if let Some(tr) = &self.trace {
                    tr.requeued(a.id, Instant::now());
                }
                inner.run.push_front(a);
            }
        }
        if let (Some(tr), Some(s0)) = (&self.trace, scan_start) {
            tr.coalesce(leader_id, lanes.len() as u32, s0, Instant::now());
        }

        let n_lanes = lanes.len();
        inner.stepping += n_lanes;
        drop(inner);

        // -- one engine call for all lanes, lock released ---------------------
        let kind = lanes[0].1.kind();
        let bucket = lanes[0].1.bucket();
        let used: usize = lanes.iter().map(|l| l.1.used_positions()).sum();
        let mut padded: usize = lanes.iter().map(|l| l.1.padded_positions()).sum();
        // whole-lane padding: the executor rounds the lane count up to its
        // b_ladder bucket, and every slot of those padding lanes is waste.
        // (Computed from the same ladder the engine picks from; like
        // `batch_occupancy` it assumes batched dispatch — a solo-loop
        // fallback pads nothing.)
        let mut b_dispatch = 1;
        // coalescing-induced padding only (whole-lane + promotion): the
        // governor's waste ceiling judges THIS, not the plans' own
        // bucket-mask waste, which narrowing could never remove
        let mut coalesce_padded: usize =
            lanes.iter().flat_map(|l| &l.2).map(|p| p.extra_positions).sum();
        if n_lanes > 1 {
            if let Ok(b) = buckets::pick(&self.b_ladder, n_lanes) {
                let whole_lane = (b - n_lanes) * lanes[0].1.slots();
                padded += whole_lane;
                coalesce_padded += whole_lane;
                b_dispatch = b;
            }
        }
        self.metrics
            .coalesce_padded_slots
            .fetch_add(coalesce_padded as u64, Ordering::Relaxed);
        let mut actives: Vec<Active> = Vec::with_capacity(n_lanes);
        let mut plans: Vec<StepPlan> = Vec::with_capacity(n_lanes);
        let mut promos: Vec<Option<Promotion>> = Vec::with_capacity(n_lanes);
        // content addresses for publish-after-forward (promoted lanes are
        // skipped: their padded plan is not the session's own refresh)
        let mut keys: Vec<Option<PrefixKey>> = Vec::with_capacity(n_lanes);
        for (a, p, promo) in lanes {
            keys.push(if self.cfg.prefix_share && promo.is_none() {
                Self::prefix_key(&p)
            } else {
                None
            });
            actives.push(a);
            plans.push(p);
            promos.push(promo);
        }
        // retained duplicates: the executor consumes every lane's plan even
        // when that lane fails, so per-lane retry needs a second consumable
        // copy (`StepPlan::duplicate` dups the Cached KV handle) to hand
        // back via `cancel_plan`. Successful lanes just drop theirs —
        // refcounts stay balanced either way. Promoted lanes carry no
        // duplicate: their plan was padded into the leader's bucket and is
        // no longer the session's own, so they fail as before.
        let retained: Vec<Option<StepPlan>> = plans
            .iter()
            .zip(&promos)
            .map(|(p, promo)| {
                if self.cfg.max_step_retries > 0 && promo.is_none() {
                    Some(p.duplicate())
                } else {
                    None
                }
            })
            .collect();
        let t0 = Instant::now();
        let mut outs = if n_lanes == 1 {
            vec![execute_plan(self.exec.as_ref(), plans.pop().expect("one plan"))]
        } else {
            self.exec.execute_batch(plans)
        };
        let fwd_wall = t0.elapsed();
        if let Some(tr) = &self.trace {
            // a coalesced batch is ONE span on the leader's track, lane
            // count annotated — this is what makes governor width decisions
            // visually auditable in Perfetto
            tr.forward(kind, leader_id, n_lanes as u32, t0, t0 + fwd_wall);
        }
        if outs.len() != n_lanes {
            // a misbehaving executor must not strand tickets: every lane
            // books SOME outcome (excess results are dropped, missing lanes
            // fail) — the PR-2 every-ticket-resolves invariant holds even
            // against a broken `execute_batch` override
            let got = outs.len();
            outs.truncate(n_lanes);
            while outs.len() < n_lanes {
                outs.push(Err(anyhow!(
                    "executor returned {got} results for {n_lanes} lanes"
                )));
            }
        }
        self.note_forward(kind, n_lanes, used, padded, b_dispatch, bucket);
        self.steps_total.fetch_add(n_lanes as u64, Ordering::Relaxed);

        // apply each lane (commits decodes; booking needs the lock again);
        // promoted lanes slice their outputs back to the planned shape
        // first, so `apply` observes exactly what solo execution would have
        // returned
        let mut landed: Vec<(Active, Result<StepOutcome>)> = Vec::with_capacity(n_lanes);
        for ((((mut active, out), promo), key), kept) in
            actives.into_iter().zip(outs).zip(promos).zip(keys).zip(retained)
        {
            active.session.add_busy(fwd_wall);
            let outcome = match out {
                Ok(o) => {
                    active.attempts = 0;
                    active.backoff_until = None;
                    let demoted = match &promo {
                        Some(p) => p.demote(o, self.arch.vocab, &self.arch),
                        None => Ok(o),
                    };
                    match demoted {
                        Ok(o) => {
                            let o = self.maybe_publish(key, o);
                            self.apply_traced(&mut active, o)
                        }
                        Err(e) => Err(e),
                    }
                }
                // per-lane routing: a faulted lane degrades or retries via
                // its retained duplicate; innocent lanes in the same batch
                // are untouched (they matched the Ok arm above)
                Err(e) => match kept {
                    Some(plan) => self.route_failure(&mut active, plan, e),
                    None => Err(e),
                },
            };
            landed.push((active, outcome));
        }

        let mut inner = self.inner.lock().unwrap();
        inner.stepping -= n_lanes;
        let now = Instant::now();
        inner.fwd_rate.note(now);
        inner.lane_rate.note_n(now, n_lanes as u64);
        for (active, outcome) in landed {
            inner.rate.note(now);
            self.book(&mut inner, active, outcome);
        }
        self.update_gauges(&mut inner);
        if inner.stepping == 0 {
            // shutdown() may be waiting for mid-step sessions to land
            self.quiesce.notify_all();
        }
        Some(leader_id)
    }

    /// Republish gauges under the run-queue lock. Spill-freed bytes are
    /// drained from the store here and fed to the trailing free-rate meter
    /// (alongside reservation releases) so `retry_after_ms` hints reflect
    /// both ways memory comes back.
    fn update_gauges(&self, inner: &mut Inner) {
        let freed = self.store.take_spill_freed_bytes();
        if freed > 0 {
            inner.free_rate.note_n(Instant::now(), freed as u64);
        }
        let m = &self.metrics;
        m.active_sessions.store(
            (inner.run.len() + inner.stepping + inner.admitting) as u64,
            Ordering::Relaxed,
        );
        m.kv_pool_bytes.store(inner.pool.reserved_bytes() as u64, Ordering::Relaxed);
        // legacy gauge: "resident caches dropped to stay under the soft
        // limit" — spills are the tiered successor of evictions, so the
        // two counters are summed here to keep the gauge's meaning
        m.kv_pool_evictions
            .store(inner.pool.evictions() + self.store.spills(), Ordering::Relaxed);
        m.kv_pool_rejections.store(inner.pool.rejections(), Ordering::Relaxed);
        m.kv_accounting_anomalies.store(inner.pool.anomalies(), Ordering::Relaxed);
        m.kv_hot_bytes.store(self.store.hot_bytes() as u64, Ordering::Relaxed);
        m.kv_spilled_bytes.store(self.store.spilled_bytes() as u64, Ordering::Relaxed);
        m.kv_spills.store(self.store.spills(), Ordering::Relaxed);
        m.kv_rehydrates.store(self.store.rehydrates(), Ordering::Relaxed);
        m.kv_rehydrate_failures
            .store(self.store.rehydrate_failures(), Ordering::Relaxed);
        m.kv_spill_drops.store(self.store.spill_drops(), Ordering::Relaxed);
        m.kv_device_bytes.store(self.store.device_bytes() as u64, Ordering::Relaxed);
        m.kv_upload_skips.store(self.store.upload_skips(), Ordering::Relaxed);
        m.kv_device_promotions
            .store(self.store.device_promotions(), Ordering::Relaxed);
        m.kv_device_demotions
            .store(self.store.device_demotions(), Ordering::Relaxed);
        m.kv_prefix_hits.store(self.store.prefix_hits(), Ordering::Relaxed);
        m.kv_prefix_misses.store(self.store.prefix_misses(), Ordering::Relaxed);
        m.sched_steps_total
            .store(self.steps_total.load(Ordering::Relaxed), Ordering::Relaxed);
        let now = Instant::now();
        m.set_steps_per_second(inner.rate.rate(now));
        m.set_batch_occupancy_recent(Self::recent_occupancy(inner, now));
    }

    /// Lanes per forward over the trailing rate window: both meters share
    /// the window, so the divisors cancel and the ratio is exactly
    /// `lanes / forwards` among recent dispatches (0 when idle — unlike
    /// the lifetime-mean `batch_occupancy`, this recovers after a burst).
    fn recent_occupancy(inner: &Inner, now: Instant) -> f64 {
        let fwd = inner.fwd_rate.rate(now);
        if fwd > 0.0 {
            inner.lane_rate.rate(now) / fwd
        } else {
            0.0
        }
    }

    /// Recompute the windowed gauges (`steps_per_second`,
    /// `batch_occupancy_recent`) at read time. The booking path only
    /// refreshes gauges on activity, so without this an idle scheduler
    /// would report its last busy-window values forever; the `/metrics`
    /// handler calls this before serializing.
    pub fn refresh_rate_gauge(&self) {
        let inner = self.inner.lock().unwrap();
        let now = Instant::now();
        self.metrics.set_steps_per_second(inner.rate.rate(now));
        self.metrics
            .set_batch_occupancy_recent(Self::recent_occupancy(&inner, now));
    }

    /// Snapshot of in-flight sessions (`GET /sessions`). A session that is
    /// mid-step (lock released) is absent from the listing for that instant
    /// but still counts toward `active_sessions` and `max_sessions`.
    pub fn sessions(&self) -> Vec<SessionInfo> {
        let inner = self.inner.lock().unwrap();
        let now = Instant::now();
        inner
            .run
            .iter()
            .map(|a| {
                let (queue_ms, ttft_ms) = match &self.trace {
                    Some(tr) => match tr.session_timing(a.id, now) {
                        Some((q, t)) => (Some(q), t),
                        None => (None, None),
                    },
                    None => (None, None),
                };
                SessionInfo {
                    id: a.id,
                    strategy: a.session.strategy.clone(),
                    steps: a.session.steps(),
                    remaining: a.session.remaining(),
                    gen_len: a.session.req().gen_len,
                    age_secs: a.session.age().as_secs_f64(),
                    busy_ms: a.session.busy().as_secs_f64() * 1e3,
                    kv_bytes: a.session.cache_bytes(),
                    deadline_in_secs: a.deadline.map(|d| {
                        if d > now {
                            (d - now).as_secs_f64()
                        } else {
                            -((now - d).as_secs_f64())
                        }
                    }),
                    queue_ms,
                    ttft_ms,
                }
            })
            .collect()
    }

    pub fn active_sessions(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.run.len() + inner.stepping + inner.admitting
    }

    /// Start `k` background driver workers ("wd-worker-N", reusing
    /// [`ThreadPool`]), each running the pick→step→book loop. With an
    /// [`EnginePool`](crate::runtime::EnginePool) executor of `k` replicas,
    /// `k` sessions step truly in parallel. Call once; `shutdown` joins the
    /// workers. Without `spawn*`, drive the scheduler manually via `tick`
    /// (tests).
    pub fn spawn_workers(self: &Arc<Self>, k: usize) {
        let mut drivers = self.drivers.lock().unwrap();
        if drivers.is_some() {
            // already driving: replacing the pool here would join the old
            // workers, which never exit before shutdown — refuse instead
            crate::debug!("scheduler drivers already running; spawn ignored");
            return;
        }
        let k = k.max(1);
        let pool = ThreadPool::new(k);
        for _ in 0..k {
            let me = Arc::clone(self);
            pool.execute(move || me.run_loop());
        }
        *drivers = Some(pool);
    }

    /// Single-driver convenience wrapper over [`Scheduler::spawn_workers`].
    pub fn spawn(self: &Arc<Self>) {
        self.spawn_workers(1);
    }

    fn run_loop(&self) {
        while !self.stop.load(Ordering::Relaxed) {
            if self.tick().is_some() {
                continue;
            }
            let inner = self.inner.lock().unwrap();
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            if !inner.run.is_empty() {
                continue; // raced a submit/re-queue between tick() and the lock
            }
            // short timeout backstop in case a wakeup is ever lost
            let _ = self
                .work
                .wait_timeout(inner, Duration::from_millis(50))
                .unwrap();
        }
    }

    /// Stop the drivers (if spawned), wait for mid-step sessions to land
    /// (their tickets are failed by the booking path, never re-queued), and
    /// fail any still-queued sessions. Every ticket ever issued resolves.
    /// Idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        self.work.notify_all();
        // join driver workers; ThreadPool::drop drains the queue and joins
        let drivers = self.drivers.lock().unwrap().take();
        drop(drivers);
        let mut inner = self.inner.lock().unwrap();
        // externally-driven tick() calls (tests, embedders) may still be
        // mid-step: wait them out so no session can re-enter the queue
        // after the drain below
        while inner.stepping > 0 {
            inner = self.quiesce.wait(inner).unwrap();
        }
        while let Some(active) = inner.run.pop_front() {
            self.release_metered(&mut inner, active.id);
            // book the failure like any other error path so /metrics stays
            // consistent with the 500s the waiting clients observe
            self.metrics.record_request(Duration::ZERO, 0, 0, false);
            if let Some(tr) = &self.trace {
                tr.finished(active.id);
            }
            active.ticket.fulfill(Err(anyhow!("scheduler shut down")));
        }
        self.update_gauges(&mut inner);
        // every reservation was created and released exactly once by the
        // booking paths above — any anomaly is a scheduler bug
        debug_assert_eq!(inner.pool.anomalies(), 0, "kv pool accounting anomaly");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;

    fn mock_sched(cfg: SchedulerConfig) -> Arc<Scheduler> {
        let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
        Scheduler::new(exec, cfg, Arc::new(Metrics::default()))
    }

    fn spec(strategy: &str, gen_len: usize) -> SubmitSpec {
        SubmitSpec {
            strategy: strategy.into(),
            req: GenRequest::new(vec![10, 11, 12, 13], gen_len, 256),
            deadline: None,
        }
    }

    #[test]
    fn submit_tick_finish() {
        let s = mock_sched(SchedulerConfig::default());
        let ticket = s.submit(spec("full", 16)).unwrap();
        assert_eq!(s.active_sessions(), 1);
        while s.tick().is_some() {}
        assert!(ticket.is_ready());
        let r = ticket.wait().unwrap();
        assert_eq!(r.tokens_generated(), 16);
        assert_eq!(s.active_sessions(), 0);
    }

    #[test]
    fn unknown_strategy_is_start_error() {
        let s = mock_sched(SchedulerConfig::default());
        match s.submit(spec("bogus", 8)) {
            Err(e) => assert!(!e.is_backpressure()),
            Ok(_) => panic!("bogus strategy admitted"),
        }
    }

    #[test]
    fn saturation_rejects_with_backpressure() {
        let cfg = SchedulerConfig { max_sessions: 1, ..Default::default() };
        let s = mock_sched(cfg);
        let _t1 = s.submit(spec("full", 16)).unwrap();
        match s.submit(spec("full", 16)) {
            Err(e) => assert!(e.is_backpressure()),
            Ok(_) => panic!("second session admitted past max_sessions=1"),
        }
        // draining frees the slot
        while s.tick().is_some() {}
        let _t2 = s.submit(spec("full", 16)).unwrap();
    }

    #[test]
    fn saturation_check_precedes_session_construction() {
        // an over-long request fails at Strategy::start (prompt+gen > s);
        // on a saturated server the refusal must be the cheap backpressure
        // path, proving no session state was built for it
        let cfg = SchedulerConfig { max_sessions: 1, ..Default::default() };
        let s = mock_sched(cfg);
        let _hold = s.submit(spec("full", 16)).unwrap();
        match s.submit(spec("full", 400)) {
            Err(e) => assert!(
                e.is_backpressure(),
                "saturated server built the session anyway: {e}"
            ),
            Ok(_) => panic!("oversized request admitted"),
        }
    }

    #[test]
    fn failed_start_releases_pool_reservation() {
        let m = MockExec::new(256);
        let est = KvPool::estimate_bytes(&m.arch(), &m.c_ladder(256), 4 + 16);
        // the reservation for an oversized request books the largest bucket,
        // so give the budget exactly that much headroom
        let big = KvPool::estimate_bytes(&m.arch(), &m.c_ladder(256), 4 + 400);
        let s = mock_sched(SchedulerConfig {
            kv_budget_bytes: big.max(2 * est),
            ..Default::default()
        });
        // start fails (prompt+gen > s) after the reservation was taken
        match s.submit(spec("full", 400)) {
            Err(SubmitError::Start(_)) => {}
            Err(e) => panic!("expected a start error, got: {e}"),
            Ok(_) => panic!("oversized request admitted"),
        }
        // a leaked reservation (the largest bucket) would now block normal
        // admissions — both of these must fit
        let t1 = s.submit(spec("full", 16)).expect("reservation leaked");
        let t2 = s.submit(spec("full", 16)).expect("reservation leaked");
        while s.tick().is_some() {}
        t1.wait().unwrap();
        t2.wait().unwrap();
    }

    #[test]
    fn background_driver_completes_requests() {
        let s = mock_sched(SchedulerConfig::default());
        s.spawn();
        let t = s.submit(spec("window", 32)).unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.tokens_generated(), 32);
        s.shutdown();
        // post-shutdown submits are refused
        assert!(s.submit(spec("full", 8)).is_err());
    }

    #[test]
    fn multi_worker_driver_completes_requests() {
        let s = mock_sched(SchedulerConfig::default());
        s.spawn_workers(4);
        let tickets: Vec<_> = (0..8)
            .map(|i| s.submit(spec(if i % 2 == 0 { "full" } else { "window" }, 16)).unwrap())
            .collect();
        for t in tickets {
            assert_eq!(t.wait().unwrap().tokens_generated(), 16);
        }
        s.shutdown();
        assert_eq!(s.active_sessions(), 0);
    }

    #[test]
    fn shutdown_fails_queued_sessions() {
        let s = mock_sched(SchedulerConfig::default());
        let t = s.submit(spec("full", 16)).unwrap();
        s.shutdown(); // no driver spawned; session still queued
        assert!(t.wait().is_err());
    }

    #[test]
    fn coalesced_tick_batches_compatible_sessions() {
        let m = Arc::new(Metrics::default());
        let s = Scheduler::new(
            Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>,
            SchedulerConfig { max_batch: 4, ..Default::default() },
            Arc::clone(&m),
        );
        // four identical full-strategy sessions: every plan is Full@s256,
        // so each tick should carry all four lanes in one forward
        let tickets: Vec<_> = (0..4).map(|_| s.submit(spec("full", 16)).unwrap()).collect();
        while s.tick().is_some() {}
        for t in tickets {
            assert_eq!(t.wait().unwrap().tokens_generated(), 16);
        }
        use std::sync::atomic::Ordering;
        let forwards = m.fwd_full.forwards.load(Ordering::Relaxed);
        let lanes = m.fwd_full.lanes.load(Ordering::Relaxed);
        assert!(forwards > 0);
        assert_eq!(lanes, 4 * 8, "4 sessions x 8 steps each");
        assert!(
            m.batch_occupancy() > 3.9,
            "identical sessions should fill all 4 lanes: occupancy {}",
            m.batch_occupancy()
        );
    }

    #[test]
    fn coalescing_skips_incompatible_plans_without_stepping_them() {
        // a full-strategy leader cannot share a forward with a window
        // session; the window session must be skipped (not stepped, not
        // failed) and complete correctly on later ticks
        let s = mock_sched(SchedulerConfig { max_batch: 4, ..Default::default() });
        let t_full = s.submit(spec("full", 8)).unwrap();
        let t_win = s.submit(spec("window", 8)).unwrap();
        while s.tick().is_some() {}
        assert_eq!(t_full.wait().unwrap().tokens_generated(), 8);
        assert_eq!(t_win.wait().unwrap().tokens_generated(), 8);
    }

    #[test]
    fn bucket_key_matches_executable_suffixes() {
        assert_eq!(bucket_key(1, (256, 0, 0)), "b1_s256");
        assert_eq!(bucket_key(4, (256, 128, 0)), "b4_s256_c128");
        assert_eq!(bucket_key(8, (512, 256, 48)), "b8_s512_c256_r48");
    }

    /// Regression (ISSUE 4): `tick_coalesced`'s bounded scan hands skipped
    /// sessions back to the queue *front* in pick order. Under the deadline
    /// policy the next tick's leader must still be the earliest-deadline
    /// session — skipped sessions are neither stepped, lost, nor demoted
    /// behind later-deadline work.
    #[test]
    fn mismatch_requeue_preserves_deadline_order() {
        let s = mock_sched(SchedulerConfig {
            policy: Policy::Deadline,
            max_batch: 4,
            ..Default::default()
        });
        // alternating kinds so every coalescing scan skips someone; deadlines
        // are strictly increasing in submission order; the leader's request
        // is sized to finish in one tick so the earliest-deadline *skipped*
        // session must lead tick 2
        let mut tickets = Vec::new();
        let mut ids = Vec::new();
        let specs = ["full", "window", "full", "window", "full"];
        let gens = [2usize, 32, 32, 32, 32];
        for (i, strat) in specs.iter().enumerate() {
            let t = s
                .submit(SubmitSpec {
                    strategy: (*strat).into(),
                    req: GenRequest::new(vec![10, 11, 12, 13], gens[i], 256),
                    deadline: Some(Duration::from_secs(10 + i as u64)),
                })
                .unwrap();
            ids.push(t.id);
            tickets.push(t);
        }
        // tick 1: leader is the earliest deadline (full, finishes); the
        // window sessions mismatch and are skipped back to the front
        assert_eq!(s.tick(), Some(ids[0]));
        let steps: std::collections::HashMap<u64, usize> =
            s.sessions().into_iter().map(|r| (r.id, r.steps)).collect();
        assert!(!steps.contains_key(&ids[0]), "leader finished and left the queue");
        assert_eq!(steps[&ids[1]], 0, "skipped session was stepped");
        assert_eq!(steps[&ids[3]], 0, "skipped session was stepped");
        assert_eq!(steps[&ids[2]], 1, "compatible follower did not coalesce");
        assert_eq!(steps[&ids[4]], 1, "compatible follower did not coalesce");
        // tick 2: EDF order is intact — the earliest-deadline skipped
        // session leads, not whoever happens to sit at the queue front
        assert_eq!(s.tick(), Some(ids[1]));
        while s.tick().is_some() {}
        for t in tickets {
            t.wait().unwrap();
        }
    }

    #[test]
    fn cross_bucket_promotion_fills_lanes_and_completes() {
        let m = Arc::new(Metrics::default());
        let s = Scheduler::new(
            Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>,
            SchedulerConfig {
                max_batch: 2,
                coalesce_waste_pct: 60,
                ..Default::default()
            },
            Arc::clone(&m),
        );
        // different window configs bucket onto different c ladders: at
        // gen 96 the w64 layout holds 4 + 64 slots (c=128) while the w16
        // layout holds 4 + 16 (c=64) — exact-bucket coalescing can never
        // pair them, promotion pads the small plan up into the leader's
        let t_big = s.submit(spec("window:w_ex=64,a=16", 96)).unwrap();
        let t_small = s.submit(spec("window:w_ex=16,a=4", 96)).unwrap();
        while s.tick().is_some() {}
        assert_eq!(t_big.wait().unwrap().tokens_generated(), 96);
        assert_eq!(t_small.wait().unwrap().tokens_generated(), 96);
        use std::sync::atomic::Ordering;
        assert!(
            m.promoted_lanes.load(Ordering::Relaxed) > 0,
            "no lane was promoted across buckets"
        );
        assert!(
            m.promoted_padded_slots.load(Ordering::Relaxed) > 0,
            "promotions must book their padding cost"
        );
        assert!(
            m.batch_occupancy() > 1.0,
            "promotion should lift occupancy above solo: {}",
            m.batch_occupancy()
        );
    }

    #[test]
    fn promotion_disabled_by_default_keeps_exact_bucket_coalescing() {
        let m = Arc::new(Metrics::default());
        let s = Scheduler::new(
            Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>,
            SchedulerConfig { max_batch: 2, ..Default::default() },
            Arc::clone(&m),
        );
        // same mismatched-bucket workload as the promotion test (gen 96:
        // w64 -> c=128, w16 -> c=64), but with the default waste_pct=0
        let t1 = s.submit(spec("window:w_ex=64,a=16", 96)).unwrap();
        let t2 = s.submit(spec("window:w_ex=16,a=4", 96)).unwrap();
        while s.tick().is_some() {}
        t1.wait().unwrap();
        t2.wait().unwrap();
        use std::sync::atomic::Ordering;
        assert_eq!(
            m.promoted_lanes.load(Ordering::Relaxed),
            0,
            "waste_pct=0 must never promote"
        );
    }

    /// ISSUE 5 satellite: under `--policy deadline` + adaptive width, a
    /// near-deadline session at depth narrows the tick to the smallest
    /// satisfying rung — the urgent lane gets a solo (lowest-latency)
    /// quantum even though the queue depth alone would widen to the top
    /// rung; once the pressure clears, the depth target resumes.
    #[test]
    fn deadline_pressure_narrows_adaptive_tick() {
        let m = Arc::new(Metrics::default());
        let s = Scheduler::new(
            Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>,
            SchedulerConfig {
                policy: Policy::Deadline,
                max_batch: 8,
                batch_policy: BatchPolicy::Adaptive,
                ..Default::default()
            },
            Arc::clone(&m),
        );
        // one already-due session (deadline ZERO is inside any slack)
        // among seven identical deadline-less ones
        let urgent = s
            .submit(SubmitSpec {
                strategy: "full".into(),
                req: GenRequest::new(vec![10, 11, 12, 13], 2, 256),
                deadline: Some(Duration::ZERO),
            })
            .unwrap();
        let urgent_id = urgent.id;
        let rest: Vec<_> = (0..7).map(|_| s.submit(spec("full", 16)).unwrap()).collect();
        use std::sync::atomic::Ordering;
        // tick 1: depth 8 would widen to rung 8, but the due lane forces
        // the smallest satisfying rung (solo) and EDF makes it the leader
        assert_eq!(s.tick(), Some(urgent_id), "EDF must lead with the due session");
        assert_eq!(
            m.batch_width.load(Ordering::Relaxed),
            1,
            "near-deadline lane must narrow the tick to solo"
        );
        assert_eq!(urgent.wait().unwrap().tokens_generated(), 2, "urgent lane finished");
        // tick 2: pressure cleared — the depth target (7 queued) resumes
        // and widens immediately to its rung
        assert!(s.tick().is_some());
        assert_eq!(
            m.batch_width.load(Ordering::Relaxed),
            4,
            "depth target should resume once the deadline pressure clears"
        );
        while s.tick().is_some() {}
        for t in rest {
            t.wait().unwrap();
        }
    }

    /// ISSUE 4 satellite: the windowed gauges must *recover* after a burst
    /// drains — `batch_width` narrows back to solo and
    /// `batch_occupancy_recent` decays to zero (then reads ~1 under solo
    /// traffic), while the lifetime `batch_occupancy` stays wedged at the
    /// burst's mean. Uses real time: the governor dwell (200ms) and the
    /// 2s rate window are what's under test.
    #[test]
    fn adaptive_gauges_recover_after_burst_drains() {
        let m = Arc::new(Metrics::default());
        let s = Scheduler::new(
            Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>,
            SchedulerConfig {
                max_batch: 8,
                batch_policy: BatchPolicy::Adaptive,
                ..Default::default()
            },
            Arc::clone(&m),
        );
        use std::sync::atomic::Ordering;
        // burst: 8 identical sessions coalesce wide
        let tickets: Vec<_> = (0..8).map(|_| s.submit(spec("full", 16)).unwrap()).collect();
        while s.tick().is_some() {}
        for t in tickets {
            t.wait().unwrap();
        }
        // occupancy > 1 is only possible if the governor widened past solo
        // (more robust than asserting on the width gauge itself, which may
        // already have narrowed by the time the drain loop exits)
        assert!(
            m.batch_occupancy_recent() > 1.5,
            "burst occupancy not visible in the windowed gauge: {}",
            m.batch_occupancy_recent()
        );
        // idle past the rate window (2s): the windowed gauge must decay to
        // zero at read time while the lifetime mean stays at the burst's
        std::thread::sleep(Duration::from_millis(2200));
        s.refresh_rate_gauge();
        assert_eq!(m.batch_occupancy_recent(), 0.0, "windowed gauge wedged wide");
        assert!(m.batch_occupancy() > 1.5, "lifetime mean should retain the burst");
        // trickle traffic: one session at a time — the governor (dwell long
        // since elapsed) must narrow back to solo width and the windowed
        // occupancy must read ~1, not the burst's mean
        let t = s.submit(spec("full", 8)).unwrap();
        while s.tick().is_some() {}
        t.wait().unwrap();
        assert_eq!(
            m.batch_width.load(Ordering::Relaxed),
            1,
            "governor stayed wedged wide after the burst drained"
        );
        let recent = m.batch_occupancy_recent();
        assert!(
            recent > 0.0 && recent < 1.5,
            "windowed occupancy should read ~solo, got {recent}"
        );
    }

    #[test]
    fn sessions_report_busy_ms() {
        let s = mock_sched(SchedulerConfig::default());
        let _t = s.submit(spec("full", 32)).unwrap();
        s.tick();
        let rows = s.sessions();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].busy_ms >= 0.0);
        assert!(rows[0].age_secs >= 0.0);
        // --trace off (the default): no recorder, no per-session timing
        assert!(s.trace().is_none());
        assert!(rows[0].queue_ms.is_none());
        assert!(rows[0].ttft_ms.is_none());
        while s.tick().is_some() {}
    }

    #[test]
    fn trace_ring_records_lifecycle_and_ttft() {
        use crate::trace::Stage;
        let s = mock_sched(SchedulerConfig {
            trace: TraceMode::Ring,
            ..Default::default()
        });
        let t = s.submit(spec("full", 16)).unwrap();
        s.tick(); // first quantum commits the first tokens (full: 2/step)
        let rows = s.sessions();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].queue_ms.is_some(), "queue_ms missing under --trace ring");
        assert!(rows[0].ttft_ms.is_some(), "first commit landed; ttft must be set");
        while s.tick().is_some() {}
        t.wait().unwrap();
        let tr = s.trace().expect("ring mode holds a recorder");
        let ev = tr.events();
        for want in [
            Stage::Admit,
            Stage::QueueWait,
            Stage::Plan,
            Stage::Forward,
            Stage::Apply,
            Stage::Commit,
        ] {
            assert!(ev.iter().any(|e| e.stage == want), "missing stage {want:?}");
        }
        assert_eq!(tr.stages.ttft.count(), 1, "one session, one TTFT sample");
        assert!(tr.stages.queue.count() >= 1);
        assert!(tr.stages.forward_full.count() >= 1);
        assert!(
            tr.stages.interstep.count() >= 1,
            "an 8-step generation must record inter-step latency"
        );
        let j = tr.chrome_json();
        assert!(!j.get("traceEvents").as_arr().unwrap().is_empty());
    }

    #[test]
    fn trace_ring_coalesced_forward_is_one_span_with_lanes() {
        use crate::trace::Stage;
        let s = mock_sched(SchedulerConfig {
            max_batch: 4,
            trace: TraceMode::Ring,
            ..Default::default()
        });
        let tickets: Vec<_> = (0..4).map(|_| s.submit(spec("full", 16)).unwrap()).collect();
        while s.tick().is_some() {}
        for t in tickets {
            t.wait().unwrap();
        }
        let tr = s.trace().unwrap();
        let ev = tr.events();
        let wide = ev
            .iter()
            .find(|e| e.stage == Stage::Forward && e.lanes == 4)
            .expect("no 4-lane coalesced forward span recorded");
        assert_eq!(wide.kind, Some(ForwardKind::Full));
        assert!(
            ev.iter().any(|e| e.stage == Stage::Coalesce && e.lanes == 4),
            "coalescing scan span missing"
        );
        // four sessions → four TTFT samples, one per first commit
        assert_eq!(tr.stages.ttft.count(), 4);
    }

    #[test]
    fn steps_per_second_reflects_recent_activity() {
        let m = Arc::new(Metrics::default());
        let s = Scheduler::new(
            Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>,
            SchedulerConfig::default(),
            Arc::clone(&m),
        );
        let _t = s.submit(spec("full", 16)).unwrap();
        while s.tick().is_some() {}
        assert!(m.steps_per_second() > 0.0, "fresh activity must register");
        // read-time refresh keeps the gauge honest while idle (decays to 0
        // once the window has passed — windowed-decay is unit-tested on
        // RateMeter with an injected clock)
        s.refresh_rate_gauge();
        assert!(m.steps_per_second() >= 0.0);
    }

    /// Scheduler over a single chaos-wrapped mock replica, with the caller
    /// holding both the chaos plan (to break/heal) and the metrics.
    fn chaos_sched(
        cfg: SchedulerConfig,
    ) -> (Arc<crate::runtime::chaos::ChaosPlan>, Arc<Metrics>, Arc<Scheduler>) {
        use crate::runtime::chaos::{ChaosConfig, ChaosPlan};
        let plan = ChaosPlan::new(ChaosConfig::default());
        let metrics = Arc::new(Metrics::default());
        let inner: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
        let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(plan.wrap(0, inner));
        let s = Scheduler::new(exec, cfg, Arc::clone(&metrics));
        (plan, metrics, s)
    }

    #[test]
    fn transient_fault_retries_to_byte_identical_completion() {
        // fault-free baseline
        let s0 = mock_sched(SchedulerConfig::default());
        let t0 = s0.submit(spec("window", 16)).unwrap();
        while s0.tick().is_some() {}
        let baseline = t0.wait().unwrap().generated();

        let (chaos, metrics, s) = chaos_sched(SchedulerConfig {
            max_step_retries: 3,
            retry_backoff: Duration::ZERO,
            ..Default::default()
        });
        let t = s.submit(spec("window", 16)).unwrap();
        // make some progress, then break the (only) replica mid-generation
        for _ in 0..3 {
            s.tick();
        }
        chaos.break_replica(0);
        s.tick(); // forward fails: plan cancelled, retry booked
        assert_eq!(metrics.step_retries.load(Ordering::Relaxed), 1);
        chaos.heal(0);
        while s.tick().is_some() {}
        let r = t.wait().unwrap();
        assert_eq!(r.generated(), baseline, "retried steps must be byte-identical");
        assert_eq!(metrics.step_retries_exhausted.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn exhausted_retries_fail_ticket_with_transient_context() {
        let (chaos, metrics, s) = chaos_sched(SchedulerConfig {
            max_step_retries: 2,
            retry_backoff: Duration::ZERO,
            ..Default::default()
        });
        chaos.break_replica(0); // never heals: the budget must exhaust
        let t = s.submit(spec("full", 8)).unwrap();
        while s.tick().is_some() {}
        let err = t.wait().unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("transient fault persisted after 2 retry attempts"),
            "exhausted-retry error must name the budget: {msg}"
        );
        assert_eq!(metrics.step_retries.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.step_retries_exhausted.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn retries_disabled_fail_fast() {
        let (chaos, metrics, s) = chaos_sched(SchedulerConfig {
            max_step_retries: 0,
            retry_backoff: Duration::ZERO,
            ..Default::default()
        });
        chaos.break_replica(0);
        let t = s.submit(spec("full", 8)).unwrap();
        while s.tick().is_some() {}
        assert!(t.wait().is_err(), "with retries off, the first fault is fatal");
        assert_eq!(metrics.step_retries.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn lost_segment_degrades_to_recompute_and_finishes() {
        // fault-free baseline
        let s0 = mock_sched(SchedulerConfig::default());
        let t0 = s0.submit(spec("window", 16)).unwrap();
        while s0.tick().is_some() {}
        let baseline = t0.wait().unwrap().generated();

        let dir = std::env::temp_dir()
            .join(format!("wd-sched-degrade-{}", std::process::id()));
        let metrics = Arc::new(Metrics::default());
        let s = Scheduler::new(
            Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>,
            SchedulerConfig {
                // a 1-byte soft cap spills every unpinned segment at once,
                // so the session's cache lives on disk between steps
                kv_soft_bytes: 1,
                kv_spill_dir: Some(dir.clone()),
                retry_backoff: Duration::ZERO,
                ..Default::default()
            },
            Arc::clone(&metrics),
        );
        let t = s.submit(spec("window", 16)).unwrap();
        // run until a spilled segment exists, then corrupt every blob
        for _ in 0..4 {
            s.tick();
        }
        let corrupted = crate::runtime::chaos::corrupt_spill_blobs(&dir).unwrap();
        assert!(corrupted >= 1, "expected a spilled segment to corrupt");
        while s.tick().is_some() {}
        let r = t.wait().unwrap();
        assert_eq!(r.generated(), baseline, "degraded recompute must converge");
        assert!(
            metrics.degraded_recomputes.load(Ordering::Relaxed) >= 1,
            "corrupt blob must route through the degrade path"
        );
        assert_eq!(
            metrics.step_retries_exhausted.load(Ordering::Relaxed),
            0,
            "degradation must not burn retry budget"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
