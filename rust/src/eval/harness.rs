//! Eval harness: strategy × task-suite → (accuracy, agreement, tok/s,
//! latency) — the cell contents of Tables 1/2/3/6.

use std::time::Duration;

use anyhow::Result;

use super::grader::{agreement, grade};
use super::tasks::TaskInstance;
use crate::coordinator::{GenRequest, StepCounts, StepExec};
use crate::strategies::Strategy;
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct EvalOptions {
    /// Instances evaluated per suite (subsampled deterministically).
    pub n: usize,
    /// Generation length (max tokens after the prompt).
    pub gen_len: usize,
    /// Artifact sequence set.
    pub s: usize,
    pub tokens_per_step: usize,
    pub adaptive: bool,
    pub seed: u64,
    /// Optional reference decodes (full baseline) for agreement scoring.
    pub reference: Option<Vec<Vec<i32>>>,
    /// Run the first instance once untimed so lazy executable compilation
    /// never pollutes throughput numbers.
    pub warmup: bool,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions { n: 8, gen_len: 96, s: 256, tokens_per_step: 1,
                      adaptive: false, seed: 7, reference: None, warmup: true }
    }
}

#[derive(Debug, Clone)]
pub struct EvalReport {
    pub strategy: String,
    pub task: String,
    pub n: usize,
    pub accuracy: f64,
    /// Mean token agreement vs the reference decode (1.0 when no reference).
    pub agreement: f64,
    pub total_wall: Duration,
    pub total_tokens: usize,
    pub counts: StepCounts,
    /// Per-instance generated token ids (reusable as a later reference).
    pub outputs: Vec<Vec<i32>>,
    /// Per-instance latencies (secs).
    pub latencies: Vec<f64>,
}

impl EvalReport {
    pub fn tokens_per_sec(&self) -> f64 {
        let secs = self.total_wall.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.total_tokens as f64 / secs
        }
    }

    pub fn mean_latency(&self) -> f64 {
        if self.latencies.is_empty() {
            0.0
        } else {
            self.latencies.iter().sum::<f64>() / self.latencies.len() as f64
        }
    }
}

/// Deterministically subsample `n` instances.
pub fn subsample(instances: &[TaskInstance], n: usize, seed: u64) -> Vec<TaskInstance> {
    if instances.len() <= n {
        return instances.to_vec();
    }
    let mut rng = Rng::new(seed);
    rng.sample_indices(instances.len(), n)
        .into_iter()
        .map(|i| instances[i].clone())
        .collect()
}

/// Run one strategy over one suite.
pub fn run_eval(exec: &dyn StepExec, strategy: &dyn Strategy, tok: &Tokenizer,
                instances: &[TaskInstance], opts: &EvalOptions) -> Result<EvalReport> {
    let picked = subsample(instances, opts.n, opts.seed);
    let mut correct = 0usize;
    let mut agreements = Vec::new();
    let mut total_wall = Duration::ZERO;
    let mut total_tokens = 0usize;
    let mut counts = StepCounts::default();
    let mut outputs = Vec::with_capacity(picked.len());
    let mut latencies = Vec::with_capacity(picked.len());
    if opts.warmup {
        if let Some(inst) = picked.first() {
            let mut req = GenRequest::new(tok.encode(&inst.prompt), opts.gen_len, opts.s);
            req.tokens_per_step = opts.tokens_per_step;
            req.adaptive = opts.adaptive;
            let _ = strategy.generate(exec, &req)?;
        }
    }
    for (i, inst) in picked.iter().enumerate() {
        let prompt = tok.encode(&inst.prompt);
        let mut req = GenRequest::new(prompt, opts.gen_len, opts.s);
        req.tokens_per_step = opts.tokens_per_step;
        req.adaptive = opts.adaptive;
        let r = strategy.generate(exec, &req)?;
        let gen_ids = r.generated();
        let text = tok.decode(&gen_ids);
        if grade(&inst.task, &text, &inst.answer) {
            correct += 1;
        }
        if let Some(refs) = &opts.reference {
            if let Some(r_ids) = refs.get(i) {
                agreements.push(agreement(&gen_ids, r_ids));
            }
        }
        total_wall += r.wall;
        total_tokens += gen_ids.len();
        counts.full += r.counts.full;
        counts.window += r.counts.window;
        counts.cached += r.counts.cached;
        counts.token_slots += r.counts.token_slots;
        latencies.push(r.wall.as_secs_f64());
        outputs.push(gen_ids);
    }
    let task = picked.first().map(|i| i.task.clone()).unwrap_or_default();
    Ok(EvalReport {
        strategy: strategy.name(),
        task,
        n: picked.len(),
        accuracy: if picked.is_empty() { 0.0 } else { correct as f64 / picked.len() as f64 },
        agreement: if agreements.is_empty() {
            1.0
        } else {
            agreements.iter().sum::<f64>() / agreements.len() as f64
        },
        total_wall,
        total_tokens,
        counts,
        outputs,
        latencies,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;
    use crate::strategies::FullBaseline;

    fn toy_tok() -> Tokenizer {
        let mut vocab: Vec<String> =
            ["<pad>", "<mask>", "<eos>", "<bos>", "<unk>"].iter().map(|s| s.to_string()).collect();
        for i in 0..20 {
            vocab.push(format!("w{i}"));
        }
        Tokenizer::from_vocab(vocab)
    }

    fn toy_instances(n: usize) -> Vec<TaskInstance> {
        (0..n)
            .map(|i| TaskInstance {
                id: format!("t{i}"),
                task: "synth-gsm".into(),
                format: "base".into(),
                prompt: "w1 w2 w3 w4".into(),
                answer: "7".into(),
                reference: "#### 7".into(),
            })
            .collect()
    }

    #[test]
    fn subsample_deterministic() {
        let inst = toy_instances(20);
        let a = subsample(&inst, 5, 3);
        let b = subsample(&inst, 5, 3);
        assert_eq!(
            a.iter().map(|x| x.id.clone()).collect::<Vec<_>>(),
            b.iter().map(|x| x.id.clone()).collect::<Vec<_>>()
        );
        assert_eq!(a.len(), 5);
    }

    #[test]
    fn harness_runs_on_mock() {
        let m = MockExec::new(256);
        let tok = toy_tok();
        let opts = EvalOptions { n: 3, gen_len: 24, ..Default::default() };
        let rep = run_eval(&m, &FullBaseline, &tok, &toy_instances(5), &opts).unwrap();
        assert_eq!(rep.n, 3);
        assert_eq!(rep.outputs.len(), 3);
        assert_eq!(rep.total_tokens, 3 * 24);
        // mock never emits "#### 7"
        assert_eq!(rep.accuracy, 0.0);
        assert!(rep.tokens_per_sec() > 0.0);
    }

    #[test]
    fn agreement_against_self_is_one() {
        let m = MockExec::new(256);
        let tok = toy_tok();
        let opts = EvalOptions { n: 2, gen_len: 16, ..Default::default() };
        let first = run_eval(&m, &FullBaseline, &tok, &toy_instances(4), &opts).unwrap();
        let opts2 = EvalOptions { reference: Some(first.outputs.clone()), ..opts };
        let second = run_eval(&m, &FullBaseline, &tok, &toy_instances(4), &opts2).unwrap();
        assert_eq!(second.agreement, 1.0);
    }
}
