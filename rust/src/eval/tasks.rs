//! Task-suite loading. The build path (`python/compile/corpus.py`) writes
//! held-out instances to `artifacts/tasks/<task>_<fmt>.json`; these are the
//! synthetic stand-ins for GSM8K / MATH / HumanEval / MBPP (DESIGN.md §2).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::parse_file;

pub const TASKS: [&str; 4] = ["synth-gsm", "synth-math", "synth-he", "synth-mbpp"];

/// Paper-table display names for the synthetic stand-ins.
pub fn display_name(task: &str) -> &'static str {
    match task {
        "synth-gsm" => "GSM8K*",
        "synth-math" => "MATH*",
        "synth-he" => "HumanEval*",
        "synth-mbpp" => "MBPP*",
        _ => "?",
    }
}

#[derive(Debug, Clone)]
pub struct TaskInstance {
    pub id: String,
    pub task: String,
    pub format: String,
    pub prompt: String,
    pub answer: String,
    pub reference: String,
}

/// Load one suite (`synth-gsm`, …) in one format (`base`/`instruct`).
pub fn load_task(tasks_dir: &Path, task: &str, format: &str) -> Result<Vec<TaskInstance>> {
    let path = tasks_dir.join(format!("{task}_{format}.json"));
    let j = parse_file(&path).with_context(|| format!("loading {}", path.display()))?;
    let arr = j.as_arr().ok_or_else(|| anyhow!("{}: not an array", path.display()))?;
    let mut out = Vec::with_capacity(arr.len());
    for item in arr {
        let s = |k: &str| item.get(k).as_str().unwrap_or_default().to_string();
        let inst = TaskInstance {
            id: s("id"),
            task: s("task"),
            format: s("format"),
            prompt: s("prompt"),
            answer: s("answer"),
            reference: s("reference"),
        };
        if inst.prompt.is_empty() || inst.answer.is_empty() {
            return Err(anyhow!("{}: instance missing prompt/answer", path.display()));
        }
        out.push(inst);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("wdtasks-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut f = std::fs::File::create(dir.join("synth-gsm_base.json")).unwrap();
        f.write_all(
            b"[{\"id\":\"g0\",\"task\":\"synth-gsm\",\"format\":\"base\",
               \"prompt\":\"q : 1 + 1 ? a :\",\"answer\":\"2\",\"reference\":\"#### 2\"}]",
        )
        .unwrap();
        let v = load_task(&dir, "synth-gsm", "base").unwrap();
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].answer, "2");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(load_task(Path::new("/nonexistent"), "synth-gsm", "base").is_err());
    }

    #[test]
    fn display_names() {
        assert_eq!(display_name("synth-gsm"), "GSM8K*");
        assert_eq!(display_name("synth-mbpp"), "MBPP*");
    }
}
