//! Evaluation harness: loads the held-out task suites emitted by the build
//! path, runs a strategy over them, grades outputs, and reports the
//! accuracy / throughput / speedup cells of the paper's tables.

pub mod grader;
pub mod harness;
pub mod tasks;

pub use grader::{grade, Grade};
pub use harness::{run_eval, EvalOptions, EvalReport};
pub use tasks::{load_task, TaskInstance, TASKS};
