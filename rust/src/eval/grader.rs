//! Graders for the synthetic task suites.
//!
//! * math-style tasks (`synth-gsm`, `synth-math`): extract the digits after
//!   the `#### ` marker and exact-match against the reference answer;
//! * code-style tasks (`synth-he`, `synth-mbpp`): canonical-form exact match
//!   of the emitted function (whitespace-normalized token sequence).
//!
//! Besides task accuracy we grade **agreement with the full-sequence
//! reference decode** — the direct measure of "quality preserved" that the
//! paper's accuracy columns proxy (DESIGN.md §2).

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Grade {
    pub correct: bool,
    /// Token-level agreement with a reference decode in [0,1] (1 = identical).
    pub agreement: f64,
}

/// Extract the answer span after the last `####` marker.
///
/// The word-level tokenizer renders `####` as four `#` tokens, so decoded
/// text reads `... # # # # 7`. We therefore scan the *token* sequence for
/// the last run of four `#` and take the following digit tokens.
pub fn extract_answer(text: &str) -> Option<String> {
    let toks: Vec<&str> = text.split_whitespace().collect();
    let mut marker_end = None;
    let mut run = 0usize;
    for (i, t) in toks.iter().enumerate() {
        if *t == "#" {
            run += 1;
            if run >= 4 {
                marker_end = Some(i + 1);
            }
        } else {
            run = 0;
        }
    }
    // also accept a literal "####" token (python-side reference strings)
    for (i, t) in toks.iter().enumerate() {
        if t.contains("####") {
            marker_end = Some(marker_end.map_or(i + 1, |m: usize| m.max(i + 1)));
        }
    }
    let end = marker_end?;
    let digits: Vec<&str> = toks[end..]
        .iter()
        .take_while(|t| t.len() == 1 && t.chars().all(|c| c.is_ascii_digit()))
        .copied()
        .collect();
    if digits.is_empty() {
        None
    } else {
        Some(digits.join(" "))
    }
}

/// Whitespace-normalize a token string.
pub fn normalize(s: &str) -> String {
    s.split_whitespace().collect::<Vec<_>>().join(" ")
}

/// Grade a generated text against a task instance.
pub fn grade(task: &str, output: &str, answer: &str) -> bool {
    match task {
        "synth-gsm" | "synth-math" => {
            extract_answer(output).as_deref() == Some(normalize(answer).as_str())
        }
        "synth-he" | "synth-mbpp" => {
            // canonical form: the emitted `def f ...` must match exactly
            match output.find("def ") {
                Some(i) => normalize(&output[i..]).starts_with(&normalize(answer)),
                None => false,
            }
        }
        _ => false,
    }
}

/// Token-level agreement of two id sequences (prefix-aligned Hamming).
pub fn agreement(a: &[i32], b: &[i32]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    let n = a.len().max(b.len());
    let matches = a.iter().zip(b.iter()).filter(|(x, y)| x == y).count();
    matches as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extract_simple() {
        assert_eq!(extract_answer("blah #### 4 2").as_deref(), Some("4 2"));
        assert_eq!(extract_answer("no marker"), None);
        assert_eq!(extract_answer("x #### "), None);
    }

    #[test]
    fn extract_uses_last_marker() {
        assert_eq!(extract_answer("#### 1 then #### 7").as_deref(), Some("7"));
    }

    #[test]
    fn extract_stops_at_non_digit() {
        assert_eq!(extract_answer("#### 4 2 q : next").as_deref(), Some("4 2"));
    }

    #[test]
    fn grade_math_tasks() {
        assert!(grade("synth-gsm", "tom has 3 + 4 = 7 . #### 7", "7"));
        assert!(!grade("synth-gsm", "#### 8", "7"));
        assert!(grade("synth-math", "the value is 1 4 . #### 1 4", "1 4"));
    }

    #[test]
    fn grade_code_tasks() {
        let ans = "def f ( x ) : return x + 3";
        assert!(grade("synth-he", "def f ( x ) : return x + 3", ans));
        // trailing continuation after the function is fine
        assert!(grade("synth-he", "def f ( x ) : return x + 3 q : next", ans));
        assert!(!grade("synth-he", "def f ( x ) : return x + 4", ans));
        assert!(!grade("synth-he", "no function here", ans));
    }

    #[test]
    fn grade_unknown_task_false() {
        assert!(!grade("bogus", "#### 7", "7"));
    }

    #[test]
    fn agreement_basics() {
        assert_eq!(agreement(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(agreement(&[1, 2, 3], &[1, 9, 3]), 2.0 / 3.0);
        assert_eq!(agreement(&[1, 2], &[1, 2, 3, 4]), 0.5);
        assert_eq!(agreement(&[], &[]), 1.0);
    }
}
