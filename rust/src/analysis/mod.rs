//! Token-level analysis probes reproducing the paper's §3 observations:
//! Fig. 2 (prefix-local confidence), Fig. 3 (truncation KL ± cache),
//! Fig. 4 (decoded-token V stability).

pub mod confidence;
pub mod stability;
pub mod truncation;

use anyhow::Result;

use crate::coordinator::policies::{candidates, select_top_k};
use crate::coordinator::{SeqState, StepExec};

/// Drive a plain full-sequence decode to diffusion step `t_stop` (exclusive),
/// committing `k` top-confidence tokens per step — the shared setup for all
/// probes ("observe the model mid-decode").
pub fn decode_until(exec: &dyn StepExec, state: &mut SeqState, s: usize,
                    t_stop: usize, k: usize) -> Result<()> {
    let vocab = exec.arch().vocab;
    for step in 0..t_stop {
        if state.done() {
            break;
        }
        let logits = exec.full(s, &state.ids, &state.full_valid())?;
        let undecoded = state.undecoded();
        let cands = candidates(
            undecoded.iter().map(|&p| (p, &logits[p * vocab..(p + 1) * vocab])),
        );
        for c in select_top_k(cands, k) {
            state.decode(c.pos, c.token, step, false)?;
        }
    }
    Ok(())
}

/// Softmax confidence of each undecoded position under full-sequence logits.
pub fn confidence_field(exec: &dyn StepExec, state: &SeqState, s: usize)
                        -> Result<Vec<(usize, f64)>> {
    let vocab = exec.arch().vocab;
    let logits = exec.full(s, &state.ids, &state.full_valid())?;
    Ok(state
        .undecoded()
        .into_iter()
        .map(|p| {
            let (_, conf) = crate::coordinator::policies::score_row(
                &logits[p * vocab..(p + 1) * vocab],
            );
            (p, conf)
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;

    #[test]
    fn decode_until_advances() {
        let m = MockExec::new(256);
        let mut st = SeqState::new(&[10; 8], 64, 256, 1, 2, 0).unwrap();
        decode_until(&m, &mut st, 256, 10, 2).unwrap();
        assert_eq!(st.num_undecoded(), 64 - 20);
    }

    #[test]
    fn confidence_field_is_prefix_local_on_mock() {
        let m = MockExec::new(256);
        let st = SeqState::new(&[10; 8], 64, 256, 1, 2, 0).unwrap();
        let field = confidence_field(&m, &st, 256).unwrap();
        assert_eq!(field.len(), 64);
        // mock confidence decays with position
        assert!(field.first().unwrap().1 > field.last().unwrap().1);
    }
}
