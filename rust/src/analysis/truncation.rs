//! Fig. 3: KL divergence of active-token predictions under truncated
//! undecoded context vs the full-sequence reference, with and without
//! reusing the previous step's KV for non-active retained tokens (Obs. 2).

use anyhow::Result;

use super::decode_until;
use crate::coordinator::{ComputeSet, SeqState, StepExec, WindowLayout};
use crate::util::stats::{kl_divergence, softmax};

#[derive(Debug, Clone)]
pub struct TruncationPoint {
    pub w: usize,
    pub kl_nocache: f64,
    pub kl_cache: f64,
}

/// Mean KL over the active set between truncated and reference predictions.
fn mean_kl(active: &[usize], ref_probs: &[Vec<f64>], probs_of: impl Fn(usize) -> Vec<f64>)
           -> f64 {
    let mut total = 0.0;
    for (i, &p) in active.iter().enumerate() {
        total += kl_divergence(&ref_probs[i], &probs_of(p));
    }
    total / active.len().max(1) as f64
}

/// Run the Fig.-3 probe at observation step `t0`.
///
/// For each truncation width `w`:
/// * **no-cache**: forward over (decoded ∪ first-w undecoded), fresh KV;
/// * **cache**: KV of the retained window initialized at step `t0 - 1`
///   (i.e. before the last `k_per_step` decodes), then a cached step at `t0`
///   recomputing only the active tokens — exactly the reuse Window-Diffusion
///   performs on buffer tokens.
pub fn run_probe(exec: &dyn StepExec, prompt: &[i32], gen_len: usize, s: usize,
                 t0: usize, n_active: usize, widths: &[usize], k_per_step: usize)
                 -> Result<Vec<TruncationPoint>> {
    let sp = exec.special();
    let vocab = exec.arch().vocab;
    let c_ladder = exec.c_ladder(s);
    let r_ladder = exec.r_ladder(s);

    // decode to t0-1, snapshot, then one more step to t0
    let mut state = SeqState::new(prompt, gen_len, s, sp.mask, sp.eos, sp.pad)?;
    decode_until(exec, &mut state, s, t0.saturating_sub(1), k_per_step)?;
    let state_prev = state.clone();
    decode_until(exec, &mut state, s, 1, k_per_step)?;

    let active: Vec<usize> = state.undecoded_prefix(n_active);
    if active.is_empty() {
        return Ok(vec![]);
    }

    // full-sequence, no-cache reference at t0
    let full = exec.full(s, &state.ids, &state.full_valid())?;
    let ref_probs: Vec<Vec<f64>> = active
        .iter()
        .map(|&p| softmax(&full[p * vocab..(p + 1) * vocab]))
        .collect();

    let mut out = Vec::with_capacity(widths.len());
    for &w in widths {
        // ---- truncation only: fresh forward on the truncated layout -------
        let layout = WindowLayout::build(&state, w.max(n_active), &c_ladder)?;
        let (logits, _) = exec.window(
            s, layout.c, &layout.ids_padded(&state), &layout.pos_padded(),
            &layout.cvalid,
        )?;
        let kl_nocache = mean_kl(&active, &ref_probs, |p| {
            let slot = layout.slot(p).expect("active in layout");
            softmax(&logits[slot * vocab..(slot + 1) * vocab])
        });

        // ---- truncation + cache: KV from t0-1, recompute actives only -----
        // (build the same layout over the previous state so buffer KV is stale)
        let layout_prev = WindowLayout::build(&state_prev, w.max(n_active), &c_ladder)?;
        let kl_cache = if active.iter().all(|&p| layout_prev.contains(p)) {
            let (_, kv) = exec.window(
                s, layout_prev.c, &layout_prev.ids_padded(&state_prev),
                &layout_prev.pos_padded(), &layout_prev.cvalid,
            )?;
            let cs = ComputeSet::build(&state, &layout_prev, &active, &[], &r_ladder)?;
            let (clogits, _) = exec.cached(
                s, layout_prev.c, cs.r, &cs.ids_r, &cs.pos_r, &cs.slot_idx,
                &cs.rvalid, &layout_prev.cvalid, &kv,
            )?;
            mean_kl(&active, &ref_probs, |p| {
                let row = cs.positions.iter().position(|&x| x == p).unwrap();
                softmax(&clogits[row * vocab..(row + 1) * vocab])
            })
        } else {
            f64::NAN
        };

        out.push(TruncationPoint { w, kl_nocache, kl_cache });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;

    #[test]
    fn probe_shapes() {
        let m = MockExec::new(256);
        let pts = run_probe(&m, &[10; 8], 96, 256, 10, 8, &[16, 32, 64], 2).unwrap();
        assert_eq!(pts.len(), 3);
        // mock logits are position-only -> truncation changes nothing: KL ~ 0
        for p in &pts {
            assert!(p.kl_nocache < 1e-9, "{p:?}");
            assert!(p.kl_cache < 1e-9, "{p:?}");
        }
    }
}
