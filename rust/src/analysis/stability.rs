//! Fig. 4: temporal stability of decoded-token *Value* representations —
//! recently decoded tokens transiently unstable, earlier-decoded tokens
//! near-stationary across adjacent steps (Obs. 3).

use std::collections::HashMap;

use anyhow::Result;

use crate::coordinator::policies::{candidates, select_top_k};
use crate::coordinator::{SeqState, StepExec, WindowLayout};
use crate::runtime::Arch;
use crate::util::stats::cosine;

/// Per-position V vectors (all layers/heads concatenated) at one step.
type VField = HashMap<usize, Vec<f32>>;

/// Extract per-position V vectors from a window forward's cache.
fn v_field(arch: &Arch, layout: &WindowLayout, v_host: &[f32]) -> VField {
    let (l, c, h, dh) = (arch.n_layers, layout.c, arch.n_heads, arch.dh);
    let mut out = HashMap::new();
    for (slot, &p) in layout.abs.iter().enumerate() {
        let mut vec = Vec::with_capacity(l * h * dh);
        for li in 0..l {
            let base = li * c * h * dh + slot * h * dh;
            vec.extend_from_slice(&v_host[base..base + h * dh]);
        }
        out.insert(p, vec);
    }
    out
}

#[derive(Debug, Clone)]
pub struct StabilityCurves {
    /// (steps since decode, mean adjacent-step V cosine) — recently decoded.
    pub recent: Vec<(usize, f64)>,
    /// (steps since observation t0, mean V cosine) — earlier-decoded tokens.
    pub early: Vec<(usize, f64)>,
}

/// Drive a full-region windowed decode for `total_steps`, recording V fields
/// each step, then aggregate the two Fig.-4 curves.
///
/// * `recent`: for every position decoded during the run, cosine between its
///   V at decode-step+Δ and decode-step+Δ+1, averaged per Δ.
/// * `early`: the first `n_early` tokens already decoded at `t0` (excluding
///   the prompt), V cosine between step t0 and t0+Δ.
pub fn run_probe(exec: &dyn StepExec, prompt: &[i32], gen_len: usize, s: usize,
                 total_steps: usize, t0: usize, n_early: usize, horizon: usize,
                 k_per_step: usize) -> Result<StabilityCurves> {
    let sp = exec.special();
    let arch = exec.arch();
    let vocab = arch.vocab;
    let c_ladder = exec.c_ladder(s);
    let mut state = SeqState::new(prompt, gen_len, s, sp.mask, sp.eos, sp.pad)?;

    let mut fields: Vec<VField> = Vec::with_capacity(total_steps);
    for step in 0..total_steps {
        // full live-region layout: every position computed fresh each step
        let positions: Vec<usize> = (0..state.live_end()).collect();
        let layout = WindowLayout::from_positions(&state, positions, &c_ladder)?;
        let (logits, kv) = exec.window(
            s, layout.c, &layout.ids_padded(&state), &layout.pos_padded(),
            &layout.cvalid,
        )?;
        fields.push(v_field(&arch, &layout, &kv.v_host()?));
        if !state.done() {
            let undecoded = state.undecoded();
            let cands = candidates(undecoded.iter().map(|&p| {
                let slot = layout.slot(p).expect("in layout");
                (p, &logits[slot * vocab..(slot + 1) * vocab])
            }));
            for c in select_top_k(cands, k_per_step) {
                state.decode(c.pos, c.token, step, false)?;
            }
        }
    }

    // -- recent curve ---------------------------------------------------------
    let mut per_delta: HashMap<usize, Vec<f64>> = HashMap::new();
    for p in state.prompt_len..state.live_end() {
        let Some(td) = state.decoded_at[p] else { continue };
        for delta in 0..horizon {
            let (a, b) = (td + delta, td + delta + 1);
            if b >= fields.len() {
                break;
            }
            if let (Some(va), Some(vb)) = (fields[a].get(&p), fields[b].get(&p)) {
                per_delta.entry(delta).or_default().push(cosine(va, vb));
            }
        }
    }
    let mut recent: Vec<(usize, f64)> = per_delta
        .into_iter()
        .map(|(d, v)| (d, v.iter().sum::<f64>() / v.len() as f64))
        .collect();
    recent.sort_unstable_by_key(|&(d, _)| d);

    // -- early curve ------------------------------------------------------------
    let early_pos: Vec<usize> = (state.prompt_len..state.live_end())
        .filter(|&p| matches!(state.decoded_at[p], Some(t) if t < t0))
        .take(n_early)
        .collect();
    let mut early = Vec::new();
    for delta in 1..horizon {
        let t = t0 + delta;
        if t >= fields.len() {
            break;
        }
        let sims: Vec<f64> = early_pos
            .iter()
            .filter_map(|p| {
                Some(cosine(fields[t0].get(p)?, fields[t].get(p)?))
            })
            .collect();
        if !sims.is_empty() {
            early.push((delta, sims.iter().sum::<f64>() / sims.len() as f64));
        }
    }

    Ok(StabilityCurves { recent, early })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;

    #[test]
    fn probe_runs_on_mock() {
        // mock V is constant (zeros) -> curves exist; cosine of zero vectors
        // is defined as 0 in stats::cosine, so just check shapes
        let m = MockExec::new(256);
        let c = run_probe(&m, &[10; 8], 48, 256, 30, 10, 8, 8, 2).unwrap();
        assert!(!c.recent.is_empty());
        assert!(!c.early.is_empty());
        assert!(c.recent.iter().all(|&(d, _)| d < 8));
    }
}
