//! Fig. 2: token-wise prediction confidence over undecoded positions at
//! chosen diffusion steps — the prefix-locality evidence (Obs. 1).

use anyhow::Result;

use super::{confidence_field, decode_until};
use crate::coordinator::{SeqState, StepExec};

/// One heatmap row: the confidence field at a snapshot step.
#[derive(Debug, Clone)]
pub struct ConfidenceSnapshot {
    pub step: usize,
    /// (absolute position, confidence) for every undecoded position.
    pub field: Vec<(usize, f64)>,
}

/// Fraction of total top-confidence mass in the first `frac_window` of the
/// undecoded region — the scalar the bench asserts prefix locality with.
pub fn prefix_mass(snap: &ConfidenceSnapshot, frac_window: f64) -> f64 {
    if snap.field.is_empty() {
        return 0.0;
    }
    let cut = (snap.field.len() as f64 * frac_window).ceil() as usize;
    let total: f64 = snap.field.iter().map(|(_, c)| c).sum();
    if total <= 0.0 {
        return 0.0;
    }
    snap.field.iter().take(cut).map(|(_, c)| c).sum::<f64>() / total
}

/// Run a full-sequence decode, snapshotting the confidence field at `steps`.
pub fn run_probe(exec: &dyn StepExec, prompt: &[i32], gen_len: usize, s: usize,
                 snapshot_steps: &[usize], k_per_step: usize)
                 -> Result<Vec<ConfidenceSnapshot>> {
    let sp = exec.special();
    let mut state = SeqState::new(prompt, gen_len, s, sp.mask, sp.eos, sp.pad)?;
    let mut out = Vec::new();
    let mut cur = 0usize;
    let mut steps = snapshot_steps.to_vec();
    steps.sort_unstable();
    for &t in &steps {
        decode_until(exec, &mut state, s, t.saturating_sub(cur), k_per_step)?;
        cur = t;
        out.push(ConfidenceSnapshot { step: t, field: confidence_field(exec, &state, s)? });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;

    #[test]
    fn snapshots_at_requested_steps() {
        let m = MockExec::new(256);
        let snaps = run_probe(&m, &[10; 8], 96, 256, &[4, 12], 2).unwrap();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[0].step, 4);
        assert_eq!(snaps[0].field.len(), 96 - 8);
        assert_eq!(snaps[1].field.len(), 96 - 24);
    }

    #[test]
    fn mock_mass_concentrates_at_prefix() {
        let m = MockExec::new(256);
        let snaps = run_probe(&m, &[10; 8], 96, 256, &[8], 2).unwrap();
        // first 25% of undecoded region holds >25% of confidence mass
        assert!(prefix_mass(&snaps[0], 0.25) > 0.25);
    }
}
