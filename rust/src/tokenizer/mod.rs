//! Rust mirror of `python/compile/tokenizer.py`.
//!
//! Same algorithm: whitespace-separated words, digits always singleton tokens,
//! letter/underscore runs and single punctuation chars as tokens, closed
//! vocabulary with fixed special ids. Parity with the python implementation is
//! enforced by golden vectors baked into `artifacts/vocab.json`
//! (see `tests/integration.rs::tokenizer_parity`).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::parse_file;

pub const PAD: i32 = 0;
pub const MASK: i32 = 1;
pub const EOS: i32 = 2;
pub const BOS: i32 = 3;
pub const UNK: i32 = 4;
pub const NUM_SPECIALS: usize = 5;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: Vec<String>,
    index: HashMap<String, i32>,
}

/// Split text into surface tokens exactly like python's `pretokenize`:
/// `[A-Za-z_]+ | [0-9] | single non-alnum-non-ws char`.
pub fn pretokenize(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut chars = text.chars().peekable();
    while let Some(&c) = chars.peek() {
        if c.is_whitespace() {
            chars.next();
        } else if c.is_ascii_alphabetic() || c == '_' {
            let mut word = String::new();
            while let Some(&c2) = chars.peek() {
                if c2.is_ascii_alphabetic() || c2 == '_' {
                    word.push(c2);
                    chars.next();
                } else {
                    break;
                }
            }
            out.push(word);
        } else if c.is_ascii_digit() {
            out.push(c.to_string());
            chars.next();
        } else {
            out.push(c.to_string());
            chars.next();
        }
    }
    out
}

impl Tokenizer {
    pub fn from_vocab(vocab: Vec<String>) -> Tokenizer {
        let index = vocab
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as i32))
            .collect();
        Tokenizer { vocab, index }
    }

    /// Load from `artifacts/vocab.json` (written by aot.py).
    pub fn load(path: &Path) -> Result<Tokenizer> {
        let payload = parse_file(path)?;
        let vocab = payload
            .get("vocab")
            .as_arr()
            .ok_or_else(|| anyhow!("vocab.json: missing 'vocab' array"))?
            .iter()
            .map(|v| v.as_str().map(str::to_string))
            .collect::<Option<Vec<_>>>()
            .context("vocab.json: non-string vocab entry")?;
        if vocab.len() < NUM_SPECIALS {
            return Err(anyhow!("vocab.json: fewer than {NUM_SPECIALS} entries"));
        }
        Ok(Tokenizer::from_vocab(vocab))
    }

    /// Golden (text, ids) pairs emitted by python for the parity test.
    pub fn load_golden(path: &Path) -> Result<Vec<(String, Vec<i32>)>> {
        let payload = parse_file(path)?;
        let mut out = Vec::new();
        if let Some(arr) = payload.get("golden").as_arr() {
            for g in arr {
                let text = g.get("text").as_str().unwrap_or_default().to_string();
                let ids = g
                    .get("ids")
                    .as_arr()
                    .map(|a| a.iter().filter_map(|x| x.as_i64()).map(|x| x as i32).collect())
                    .unwrap_or_default();
                out.push((text, ids));
            }
        }
        Ok(out)
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        pretokenize(text)
            .into_iter()
            .map(|tok| self.index.get(&tok).copied().unwrap_or(UNK))
            .collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let mut words = Vec::new();
        for &i in ids {
            if (i as usize) < NUM_SPECIALS {
                continue;
            }
            match self.vocab.get(i as usize) {
                Some(w) => words.push(w.as_str()),
                None => words.push("<unk>"),
            }
        }
        words.join(" ")
    }

    /// Decode stopping at the first `<eos>` (adaptive-termination output).
    pub fn decode_until_eos(&self, ids: &[i32]) -> String {
        let end = ids.iter().position(|&i| i == EOS).unwrap_or(ids.len());
        self.decode(&ids[..end])
    }

    pub fn len(&self) -> usize {
        self.vocab.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vocab.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tokenizer {
        let mut vocab: Vec<String> =
            ["<pad>", "<mask>", "<eos>", "<bos>", "<unk>"].iter().map(|s| s.to_string()).collect();
        for w in ["tom", "has", "apples", ".", "3", "7", "+", "(", ")", "def", "f", "x", ":"] {
            vocab.push(w.to_string());
        }
        Tokenizer::from_vocab(vocab)
    }

    #[test]
    fn pretokenize_digits_split() {
        assert_eq!(pretokenize("42 apples"), vec!["4", "2", "apples"]);
    }

    #[test]
    fn pretokenize_punct_and_words() {
        assert_eq!(
            pretokenize("f ( x ) : x+1"),
            vec!["f", "(", "x", ")", ":", "x", "+", "1"]
        );
    }

    #[test]
    fn pretokenize_underscore_words() {
        assert_eq!(pretokenize("my_var=2"), vec!["my_var", "=", "2"]);
    }

    #[test]
    fn encode_known_and_unknown() {
        let t = toy();
        let ids = t.encode("tom has 3 bananas");
        assert_eq!(ids[0], 5); // tom
        assert_eq!(*ids.last().unwrap(), UNK);
    }

    #[test]
    fn decode_skips_specials() {
        let t = toy();
        assert_eq!(t.decode(&[MASK, 5, 6, EOS, 7]), "tom has apples");
    }

    #[test]
    fn decode_until_eos_stops() {
        let t = toy();
        assert_eq!(t.decode_until_eos(&[5, 6, EOS, 7]), "tom has");
    }

    #[test]
    fn roundtrip_known_text() {
        let t = toy();
        let text = "tom has 3 apples .";
        assert_eq!(t.decode(&t.encode(text)), text);
    }

    #[test]
    fn out_of_range_id_decodes_unk() {
        let t = toy();
        assert_eq!(t.decode(&[9999]), "<unk>");
    }
}
