//! Block Diffusion baseline [Arriola et al. 2025], as the paper compares it
//! in Table 1: autoregressive over blocks, diffusion within a block, applied
//! at inference time with attention truncated at the current block's end.
//! No KV caching (Table 1 isolates the pruning scheme).
//!
//! Contrast with Window-Diffusion: the computation window is the *rigid*
//! prefix `[0, block_end)` and decoding cannot proceed past the block until
//! the whole block is decoded — exactly the constrained update order the
//! paper criticizes (and why its Instruct-model accuracy collapses at L=16).

use std::time::Instant;

use anyhow::{anyhow, Result};

use super::{commit, Strategy};
use crate::coordinator::policies::{candidates, select_top_k, DecodeSchedule};
use crate::coordinator::{GenRequest, GenResult, SeqState, StepCounts, StepExec,
                         WindowLayout};

pub struct BlockDiffusion {
    pub size: usize,
}

impl Strategy for BlockDiffusion {
    fn name(&self) -> String {
        format!("block[{}]", self.size)
    }

    fn generate(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<GenResult> {
        assert!(self.size >= 1);
        let sp = exec.special();
        let vocab = exec.arch().vocab;
        let c_ladder = exec.c_ladder(req.s);
        let mut state = SeqState::new(&req.prompt, req.gen_len, req.s, sp.mask,
                                      sp.eos, sp.pad)?;
        let schedule = DecodeSchedule::fixed(req.tokens_per_step);
        let mut counts = StepCounts::default();
        let t0 = Instant::now();
        let mut step = 0usize;

        while !state.done() {
            if step >= req.step_cap() {
                return Err(anyhow!("step cap {} exceeded", req.step_cap()));
            }
            // current block: starts at the frontier, rounded to block grid
            let frontier = state.frontier().expect("not done");
            let block_start = state.prompt_len
                + ((frontier - state.prompt_len) / self.size) * self.size;
            let block_end = (block_start + self.size).min(state.live_end());

            // decode the whole block before moving on
            while state.undecoded().iter().any(|&p| p < block_end) {
                if step >= req.step_cap() {
                    return Err(anyhow!("step cap {} exceeded", req.step_cap()));
                }
                // attention sees only [0, block_end): prefix + current block
                let positions: Vec<usize> = (0..block_end).collect();
                let layout = WindowLayout::from_positions(&state, positions, &c_ladder)?;
                let (logits, _kv) = exec.window(
                    req.s,
                    layout.c,
                    &layout.ids_padded(&state),
                    &layout.pos_padded(),
                    &layout.cvalid,
                )?;
                counts.window += 1;
                counts.token_slots += layout.c;
                let block_cands: Vec<usize> = state
                    .undecoded()
                    .into_iter()
                    .filter(|&p| p >= block_start && p < block_end)
                    .collect();
                let cands = candidates(block_cands.iter().map(|&p| {
                    let slot = layout.slot(p).expect("block pos in layout");
                    (p, &logits[slot * vocab..(slot + 1) * vocab])
                }));
                let picked = select_top_k(cands, schedule.at(step));
                if picked.is_empty() {
                    return Err(anyhow!("no block candidates at step {step}"));
                }
                commit(&mut state, &picked, step, req.adaptive)?;
                step += 1;
                if state.done() {
                    break;
                }
            }
        }
        Ok(GenResult { state, steps: step, counts, wall: t0.elapsed() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;

    #[test]
    fn decodes_block_by_block() {
        let m = MockExec::new(256);
        let b = BlockDiffusion { size: 16 };
        let mut req = GenRequest::new(vec![10, 11, 12, 13], 48, 256);
        req.tokens_per_step = 1;
        let r = b.generate(&m, &req).unwrap();
        assert!(r.state.done());
        // strict block order: every token in block 0 decoded before block 1
        let at = |p: usize| r.state.decoded_at[p].unwrap();
        let max_b0 = (4..20).map(at).max().unwrap();
        let min_b1 = (20..36).map(at).min().unwrap();
        assert!(max_b0 < min_b1);
    }

    #[test]
    fn never_sees_future_blocks() {
        // token_slots accounting: each step computes at most the c-bucket of
        // [0, block_end), never the full sequence
        let m = MockExec::new(256);
        let b = BlockDiffusion { size: 32 };
        let req = GenRequest::new(vec![10; 8], 64, 256);
        let r = b.generate(&m, &req).unwrap();
        // largest layout = 8 + 64 = 72 -> bucket 128 < 256
        assert!(r.counts.token_slots <= r.steps * 128);
        assert_eq!(r.counts.full, 0);
        assert_eq!(r.counts.cached, 0);
    }

    #[test]
    fn adaptive_eos_stops_block_walk() {
        let m = MockExec::new(256).with_eos_at(30);
        let b = BlockDiffusion { size: 16 };
        let mut req = GenRequest::new(vec![10; 4], 128, 256);
        req.adaptive = true;
        let r = b.generate(&m, &req).unwrap();
        assert_eq!(r.state.eos_pos, Some(30));
        assert!(r.tokens_generated() <= 27);
    }
}
