//! Block Diffusion baseline [Arriola et al. 2025], as the paper compares it
//! in Table 1: autoregressive over blocks, diffusion within a block, applied
//! at inference time with attention truncated at the current block's end.
//! No KV caching (Table 1 isolates the pruning scheme).
//!
//! Contrast with Window-Diffusion: the computation window is the *rigid*
//! prefix `[0, block_end)` and decoding cannot proceed past the block until
//! the whole block is decoded — exactly the constrained update order the
//! paper criticizes (and why its Instruct-model accuracy collapses at L=16).

use anyhow::{anyhow, Result};

use super::machine::{Session, SessionCore, StepMachine, StepOutcome};
use super::{commit, Strategy};
use crate::coordinator::policies::{candidates, select_top_k, DecodeSchedule};
use crate::coordinator::{GenRequest, Planned, StepExec, StepOutputs, StepPlan, WindowLayout};

pub struct BlockDiffusion {
    pub size: usize,
}

/// Context carried from `plan` to `apply`: the step's layout and the block
/// bounds decode selection is restricted to.
struct BlockPending {
    layout: WindowLayout,
    block_start: usize,
    block_end: usize,
}

/// Continuation state: the current block's bounds, held fixed until every
/// position below `block_end` is decoded (legacy inner-loop semantics — the
/// bounds do NOT track a live-region shrink mid-block).
struct BlockMachine {
    size: usize,
    vocab: usize,
    schedule: DecodeSchedule,
    c_ladder: Vec<usize>,
    cur_block: Option<(usize, usize)>,
    pending: Option<BlockPending>,
}

impl StepMachine for BlockMachine {
    fn plan(&mut self, core: &mut SessionCore) -> Result<Planned> {
        debug_assert!(self.pending.is_none(), "plan while a plan is outstanding");
        if core.state.done() {
            return Ok(Planned::Finished);
        }
        core.cap_guard()?;
        // keep the block while anything below its end is undecoded,
        // otherwise advance to the frontier's block
        let (block_start, block_end) = match self.cur_block {
            Some((bs, be)) if core.state.undecoded().iter().any(|&p| p < be) => (bs, be),
            _ => {
                let frontier = core.state.frontier().expect("not done");
                let bs = core.state.prompt_len
                    + ((frontier - core.state.prompt_len) / self.size) * self.size;
                let be = (bs + self.size).min(core.state.live_end());
                self.cur_block = Some((bs, be));
                (bs, be)
            }
        };
        // attention sees only [0, block_end): prefix + current block
        let positions: Vec<usize> = (0..block_end).collect();
        let layout = WindowLayout::from_positions(&core.state, positions, &self.c_ladder)?;
        let plan = StepPlan::Window {
            s: core.req.s,
            c: layout.c,
            ids: layout.ids_padded(&core.state),
            pos: layout.pos_padded(),
            valid: layout.cvalid.clone(),
        };
        self.pending = Some(BlockPending { layout, block_start, block_end });
        Ok(Planned::Forward(plan))
    }

    fn apply(&mut self, core: &mut SessionCore, out: StepOutputs) -> Result<StepOutcome> {
        let BlockPending { layout, block_start, block_end } = self
            .pending
            .take()
            .ok_or_else(|| anyhow!("apply without an outstanding plan"))?;
        // the block baseline never reuses KV: outputs' cache is dropped
        let logits = out.logits();
        core.counts.window += 1;
        core.counts.token_slots += layout.c;
        let block_cands: Vec<usize> = core
            .state
            .undecoded()
            .into_iter()
            .filter(|&p| p >= block_start && p < block_end)
            .collect();
        let cands = candidates(block_cands.iter().map(|&p| {
            let slot = layout.slot(p).expect("block pos in layout");
            (p, &logits[slot * self.vocab..(slot + 1) * self.vocab])
        }));
        let picked = select_top_k(cands, self.schedule.at(core.step));
        if picked.is_empty() {
            return Err(anyhow!("no block candidates at step {}", core.step));
        }
        commit(&mut core.state, &picked, core.step, core.req.adaptive)?;
        core.step += 1;
        Ok(if core.state.done() { StepOutcome::Finished } else { StepOutcome::Running })
    }

    fn cancel(&mut self, _plan: StepPlan) {
        self.pending = None;
    }
}

impl Strategy for BlockDiffusion {
    fn name(&self) -> String {
        format!("block[{}]", self.size)
    }

    fn start(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<Session> {
        assert!(self.size >= 1);
        let core = SessionCore::new(exec, req)?;
        let machine = BlockMachine {
            size: self.size,
            vocab: exec.arch().vocab,
            schedule: DecodeSchedule::fixed(req.tokens_per_step),
            c_ladder: exec.c_ladder(req.s),
            cur_block: None,
            pending: None,
        };
        Ok(Session::new(self.name(), core, Box::new(machine)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;

    #[test]
    fn decodes_block_by_block() {
        let m = MockExec::new(256);
        let b = BlockDiffusion { size: 16 };
        let mut req = GenRequest::new(vec![10, 11, 12, 13], 48, 256);
        req.tokens_per_step = 1;
        let r = b.generate(&m, &req).unwrap();
        assert!(r.state.done());
        // strict block order: every token in block 0 decoded before block 1
        let at = |p: usize| r.state.decoded_at[p].unwrap();
        let max_b0 = (4..20).map(at).max().unwrap();
        let min_b1 = (20..36).map(at).min().unwrap();
        assert!(max_b0 < min_b1);
    }

    #[test]
    fn never_sees_future_blocks() {
        // token_slots accounting: each step computes at most the c-bucket of
        // [0, block_end), never the full sequence
        let m = MockExec::new(256);
        let b = BlockDiffusion { size: 32 };
        let req = GenRequest::new(vec![10; 8], 64, 256);
        let r = b.generate(&m, &req).unwrap();
        // largest layout = 8 + 64 = 72 -> bucket 128 < 256
        assert!(r.counts.token_slots <= r.steps * 128);
        assert_eq!(r.counts.full, 0);
        assert_eq!(r.counts.cached, 0);
    }

    #[test]
    fn adaptive_eos_stops_block_walk() {
        let m = MockExec::new(256).with_eos_at(30);
        let b = BlockDiffusion { size: 16 };
        let mut req = GenRequest::new(vec![10; 4], 128, 256);
        req.adaptive = true;
        let r = b.generate(&m, &req).unwrap();
        assert_eq!(r.state.eos_pos, Some(30));
        assert!(r.tokens_generated() <= 27);
    }
}
