//! Inference strategies: the paper's Window-Diffusion and every comparison
//! baseline, all written against [`StepExec`] so the same code path runs on
//! the real PJRT engine, the serving layer's shared engine cell, and the
//! mock (tests).
//!
//! | strategy            | paper role                                   |
//! |---------------------|----------------------------------------------|
//! | `full`              | original model (Table 2 "Dream"/"LLaDA" row) |
//! | `window`            | Window-Diffusion (pruning + phase KV cache)  |
//! | `window-nocache`    | pruning-only ablation (Table 1)              |
//! | `block`             | Block Diffusion (Table 1 baseline)           |
//! | `dkv`               | dKV-Cache [Ma et al. 2025]                   |
//! | `fastdllm-prefix`   | Fast-dLLM Prefix-Cache [Wu et al. 2025]      |
//! | `fastdllm-dual`     | Fast-dLLM Dual-Cache                         |

mod block;
mod dkv;
mod fastdllm;
mod full;
pub mod machine;
mod window;

use anyhow::{anyhow, Result};

pub use block::BlockDiffusion;
pub use dkv::DkvCache;
pub use fastdllm::{FastDllmDual, FastDllmPrefix};
pub use full::FullBaseline;
pub use machine::{Session, SessionCore, StepMachine, StepOutcome};
pub use window::{WdConfig, WindowDiffusion};

use crate::coordinator::policies::Candidate;
use crate::coordinator::{GenRequest, GenResult, SeqState, StepExec};

/// A decoding strategy, written as a resumable step-machine over the
/// plan/apply protocol (`coordinator::plan`).
///
/// `start` captures all per-request state in a [`Session`]; each
/// `Session::step` advances one diffusion step (internally
/// plan → execute → apply, which is also what lets the scheduler batch
/// compatible plans across sessions into one forward). `generate` is the
/// run-to-completion compat shim (eval harness, benches, CLI) and is
/// byte-identical to driving `step` in a loop — it *is* that loop.
pub trait Strategy: Send + Sync {
    fn name(&self) -> String;

    /// Begin a session: build sequence state + the strategy's machine.
    /// Cheap (no forward passes) — safe to call on the submission path.
    fn start(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<Session>;

    /// Run-to-completion shim over `start` + `step`.
    fn generate(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<GenResult> {
        let mut session = self.start(exec, req)?;
        while let StepOutcome::Running = session.step(exec)? {}
        Ok(session.into_result())
    }
}

/// Commit picked candidates into the state.
pub(crate) fn commit(state: &mut SeqState, picked: &[Candidate], step: usize,
                     adaptive: bool) -> Result<()> {
    for c in picked {
        state.decode(c.pos, c.token, step, adaptive)?;
    }
    Ok(())
}

/// Build a strategy by name (CLI / bench / server dispatch).
/// Names accept parameter suffixes: `window:w_ex=64,a=16,refresh=32`,
/// `block:size=32`, `dkv:interval=4`, `fastdllm-prefix:block=32`.
pub fn from_name(spec: &str) -> Result<Box<dyn Strategy>> {
    let (name, args) = match spec.split_once(':') {
        Some((n, a)) => (n, a),
        None => (spec, ""),
    };
    let get = |key: &str, default: usize| -> usize {
        args.split(',')
            .filter_map(|kv| kv.split_once('='))
            .find(|(k, _)| *k == key)
            .and_then(|(_, v)| v.parse().ok())
            .unwrap_or(default)
    };
    Ok(match name {
        "full" => Box::new(FullBaseline),
        "window" => Box::new(WindowDiffusion::new(WdConfig {
            w_ex: get("w_ex", 64),
            a: get("a", 16),
            refresh: get("refresh", 32),
            cache: true,
        })),
        "window-nocache" => Box::new(WindowDiffusion::new(WdConfig {
            w_ex: get("w_ex", 64),
            a: get("a", 16),
            refresh: get("refresh", 32),
            cache: false,
        })),
        "block" => Box::new(BlockDiffusion { size: get("size", 32) }),
        "dkv" => Box::new(DkvCache { interval: get("interval", 4) }),
        "fastdllm-prefix" => Box::new(FastDllmPrefix { block: get("block", 32) }),
        "fastdllm-dual" => Box::new(FastDllmDual { block: get("block", 32) }),
        other => return Err(anyhow!("unknown strategy '{other}'")),
    })
}

/// All comparison strategies of Table 2 / Table 6 in paper order.
pub fn table2_lineup() -> Vec<Box<dyn Strategy>> {
    vec![
        Box::new(FullBaseline),
        Box::new(DkvCache { interval: 4 }),
        Box::new(FastDllmPrefix { block: 32 }),
        Box::new(FastDllmDual { block: 32 }),
        Box::new(WindowDiffusion::default()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_name_defaults() {
        assert_eq!(from_name("full").unwrap().name(), "full");
        assert_eq!(from_name("window").unwrap().name(), "window[w64/a16/r32]");
        assert!(from_name("bogus").is_err());
    }

    #[test]
    fn from_name_params() {
        let s = from_name("window:w_ex=128,a=8,refresh=16").unwrap();
        assert_eq!(s.name(), "window[w128/a8/r16]");
        let b = from_name("block:size=16").unwrap();
        assert_eq!(b.name(), "block[16]");
    }

    #[test]
    fn lineup_has_five() {
        assert_eq!(table2_lineup().len(), 5);
    }
}
