//! Resumable step-machines: the session-level API behind [`Strategy`].
//!
//! Historically every strategy exposed only run-to-completion `generate()`,
//! which forced the serving layer into worker-per-request execution (a worker
//! owns the engine mutex for one step at a time but owns the *request* for
//! its whole lifetime). The scheduler needs to advance many in-flight
//! requests one diffusion step at a time, so each strategy is now written as
//! a [`StepMachine`]: `Strategy::start` captures the per-request state in a
//! [`Session`], and `Session::step` advances exactly one diffusion step
//! (possibly several engine calls when a phase boundary forces a rebuild —
//! a "quantum" is one *committed* decode step, mirroring the legacy loops).
//!
//! `Strategy::generate` survives as a compat shim (start + step-to-finish),
//! so the eval harness, benches and CLI are unchanged and the step-driven
//! path is byte-identical to the legacy one by construction (see
//! `tests/scheduler_props.rs`).
//!
//! [`Strategy`]: super::Strategy

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::plan::{execute_plan, KvOut, Planned, StepOutputs, StepPlan};
use crate::coordinator::{GenRequest, GenResult, SeqState, StepCounts, StepExec};
use crate::scheduler::kvstore::{KvHandle, KvStore};

/// Result of advancing a session by one quantum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    Running,
    Finished,
}

/// Strategy-specific continuation state (phase layouts, KV caches, block
/// cursors). Implementations live next to their strategy.
///
/// Written against the **plan/apply protocol** (`coordinator::plan`): one
/// quantum is `plan` (build the single forward request this step needs —
/// cheap, no engine calls, but may rebuild phase layouts) → execute (solo
/// or batched with other sessions' compatible plans) → `apply` (install
/// outputs, commit decodes, bump `core.step`). `step` is the provided
/// solo shim and is byte-identical to the pre-protocol code path.
///
/// Not `Send` by itself: KV caches hold `xla::Literal`s. [`Session`] asserts
/// `Send` (see its safety comment), which is the single choke point.
pub trait StepMachine {
    /// Build the next quantum's forward request. Must return `Finished`
    /// exactly when `core.state.done()`. May mutate continuation state
    /// (phase rebuilds) — replanning after `cancel` must be deterministic:
    /// same state in, same plan out.
    fn plan(&mut self, core: &mut SessionCore) -> Result<Planned>;

    /// Consume the forward outputs for the plan issued by the last `plan`
    /// call: commit decodes, install the returned KV cache, bump
    /// `core.step`.
    fn apply(&mut self, core: &mut SessionCore, out: StepOutputs) -> Result<StepOutcome>;

    /// Hand an unexecuted plan back (a batched coalescing attempt didn't
    /// include it). Machines whose plans carry their KV cache must restore
    /// it; state must end up exactly as if `plan` was never called.
    fn cancel(&mut self, plan: StepPlan) {
        drop(plan);
    }

    /// Advance one diffusion step solo: plan → execute → apply. Provided;
    /// strategies only implement the protocol methods.
    fn step(&mut self, core: &mut SessionCore, exec: &dyn StepExec) -> Result<StepOutcome> {
        match self.plan(core)? {
            Planned::Finished => Ok(StepOutcome::Finished),
            Planned::Forward(plan) => {
                let out = execute_plan(exec, plan)?;
                self.apply(core, out)
            }
        }
    }

    /// Bytes of phase-level KV cache currently resident for this session
    /// (0 when between phases or for cache-less strategies).
    fn cache_bytes(&self) -> usize {
        0
    }

    /// Drop the resident phase cache (KV-pool pressure). The next `step`
    /// must recover by refreshing — correctness is preserved, the cost is
    /// one extra refresh forward.
    fn evict_cache(&mut self) {}
}

/// Strategy-independent per-request state shared with the machine.
pub struct SessionCore {
    pub req: GenRequest,
    pub state: SeqState,
    pub counts: StepCounts,
    /// Committed diffusion steps so far (the legacy loops' `step` counter).
    pub step: usize,
    /// The KV segment store this session adopts fresh caches into. Defaults
    /// to a private [`KvStore::detached`] (no sharing, no spilling) for
    /// solo-stepped sessions; the scheduler swaps in its shared tiered
    /// store right after `start` (before any segment exists).
    pub kv: Arc<KvStore>,
}

impl SessionCore {
    pub fn new(exec: &dyn StepExec, req: &GenRequest) -> Result<SessionCore> {
        let sp = exec.special();
        let state = SeqState::new(&req.prompt, req.gen_len, req.s, sp.mask, sp.eos, sp.pad)?;
        Ok(SessionCore {
            req: req.clone(),
            state,
            counts: StepCounts::default(),
            step: 0,
            kv: KvStore::detached(),
        })
    }

    /// Turn a forward's KV output into an owned handle: fresh host bytes
    /// are adopted into this session's store (possibly spilling cold
    /// segments); a shared segment (prefix hit) passes through as-is.
    pub fn adopt_kv(&self, out: KvOut) -> Result<KvHandle> {
        match out {
            KvOut::Fresh(kv) => self.kv.insert(&kv),
            KvOut::Shared(handle) => Ok(handle),
        }
    }

    /// Step-cap guard, identical to the legacy per-iteration check.
    pub fn cap_guard(&self) -> Result<()> {
        if self.step >= self.req.step_cap() {
            return Err(anyhow!("step cap {} exceeded", self.req.step_cap()));
        }
        Ok(())
    }
}

/// One in-flight generation: core state + the strategy's machine.
pub struct Session {
    /// Normalized strategy name (e.g. `window[w64/a16/r32]`).
    pub strategy: String,
    core: SessionCore,
    machine: Box<dyn StepMachine>,
    started: Instant,
    busy: Duration,
    finished: bool,
}

// SAFETY: a Session's machine may transiently hold host tensor data
// (`xla::Literal`s) — e.g. plan input buffers mid-build. Those are plain
// owned host memory with no aliasing back into the engine (see the
// `EngineCell` safety note in runtime/engine.rs); moving them across
// threads is sound as long as access is exclusive, which `&mut self` on
// every mutating method guarantees. Phase KV itself now lives behind
// `KvHandle`s (plain ids + `Arc<KvStore>`, Send by construction).
unsafe impl Send for Session {}

impl Session {
    pub fn new(strategy: String, core: SessionCore, machine: Box<dyn StepMachine>) -> Session {
        let finished = core.state.done(); // gen_len == 0 finishes instantly
        Session {
            strategy,
            core,
            machine,
            started: Instant::now(),
            busy: Duration::ZERO,
            finished,
        }
    }

    /// Advance one diffusion step. After an error the session is dead:
    /// further calls return `Finished` without touching the engine.
    pub fn step(&mut self, exec: &dyn StepExec) -> Result<StepOutcome> {
        if self.finished {
            return Ok(StepOutcome::Finished);
        }
        let t0 = Instant::now();
        let out = self.machine.step(&mut self.core, exec);
        self.busy += t0.elapsed();
        match out {
            Ok(StepOutcome::Finished) => {
                self.finished = true;
                Ok(StepOutcome::Finished)
            }
            Ok(StepOutcome::Running) => Ok(StepOutcome::Running),
            Err(e) => {
                self.finished = true;
                Err(e)
            }
        }
    }

    /// Plan the next quantum's forward (no engine calls). A planning error
    /// kills the session, like a step error.
    pub fn plan(&mut self) -> Result<Planned> {
        if self.finished {
            return Ok(Planned::Finished);
        }
        let t0 = Instant::now();
        let out = self.machine.plan(&mut self.core);
        self.busy += t0.elapsed();
        if out.is_err() {
            self.finished = true;
        }
        out
    }

    /// Apply forward outputs for this session's outstanding plan.
    pub fn apply(&mut self, out: StepOutputs) -> Result<StepOutcome> {
        let t0 = Instant::now();
        let r = self.machine.apply(&mut self.core, out);
        self.busy += t0.elapsed();
        match r {
            Ok(StepOutcome::Finished) => {
                self.finished = true;
                Ok(StepOutcome::Finished)
            }
            Ok(StepOutcome::Running) => Ok(StepOutcome::Running),
            Err(e) => {
                self.finished = true;
                Err(e)
            }
        }
    }

    /// Hand an unexecuted plan back to the machine (coalescing skipped this
    /// session); state is restored as if `plan` was never called.
    pub fn cancel_plan(&mut self, plan: StepPlan) {
        self.machine.cancel(plan);
    }

    /// Rebind this session to a shared [`KvStore`] (the scheduler's tiered
    /// store). Must be called before the first step: segments already
    /// adopted into the previous store are not migrated.
    pub fn attach_kv_store(&mut self, store: Arc<KvStore>) {
        debug_assert_eq!(
            self.core.step, 0,
            "attach_kv_store after the session started stepping"
        );
        self.core.kv = store;
    }

    /// Attribute engine time spent on this session's behalf (the scheduler
    /// books a batched forward's wall time against every lane it carried).
    pub fn add_busy(&mut self, d: Duration) {
        self.busy += d;
    }

    pub fn is_finished(&self) -> bool {
        self.finished
    }

    /// Committed diffusion steps so far.
    pub fn steps(&self) -> usize {
        self.core.step
    }

    /// Undecoded live positions left (the scheduler's remaining-work metric).
    pub fn remaining(&self) -> usize {
        self.core.state.num_undecoded()
    }

    pub fn req(&self) -> &GenRequest {
        &self.core.req
    }

    pub fn state(&self) -> &SeqState {
        &self.core.state
    }

    /// Wall-clock age since `start()`.
    pub fn age(&self) -> Duration {
        self.started.elapsed()
    }

    /// Accumulated engine time (excludes time parked in the run queue).
    pub fn busy(&self) -> Duration {
        self.busy
    }

    /// Resident phase-cache bytes (KV pool accounting).
    pub fn cache_bytes(&self) -> usize {
        self.machine.cache_bytes()
    }

    /// Drop the resident phase cache (KV pool pressure).
    pub fn evict_cache(&mut self) {
        self.machine.evict_cache()
    }

    /// Finalize into the legacy result type. `wall` is time since `start()`,
    /// which for scheduler-driven sessions includes queueing — the honest
    /// serving latency.
    pub fn into_result(self) -> GenResult {
        GenResult {
            state: self.core.state,
            steps: self.core.step,
            counts: self.core.counts,
            wall: self.started.elapsed(),
        }
    }
}

/// Per-request KV bytes for one cached window slot: K + V, f32, all layers.
/// (`KvCache` holds `[L, c, H, Dh]` per tensor; see runtime/engine.rs.)
pub fn kv_slot_bytes(arch: &crate::runtime::Arch) -> usize {
    2 * 4 * arch.n_layers * arch.n_heads * arch.dh
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;
    use crate::strategies::{FullBaseline, Strategy};

    #[test]
    fn session_steps_to_completion() {
        let m = MockExec::new(64);
        let req = GenRequest::new(vec![10, 11, 12, 13], 32, 64);
        let mut s = FullBaseline.start(&m, &req).unwrap();
        let mut quanta = 0;
        while let StepOutcome::Running = s.step(&m).unwrap() {
            quanta += 1;
            assert!(quanta < 1000, "runaway session");
        }
        assert!(s.is_finished());
        assert_eq!(s.remaining(), 0);
        let r = s.into_result();
        assert!(r.state.done());
        assert_eq!(r.tokens_generated(), 32);
    }

    #[test]
    fn finished_session_is_inert() {
        let m = MockExec::new(64);
        let req = GenRequest::new(vec![10, 11], 8, 64);
        let mut s = FullBaseline.start(&m, &req).unwrap();
        while let StepOutcome::Running = s.step(&m).unwrap() {}
        let calls_before = m.counts();
        assert_eq!(s.step(&m).unwrap(), StepOutcome::Finished);
        assert_eq!(m.counts(), calls_before, "finished session touched the engine");
    }

    #[test]
    fn remaining_decreases_monotonically() {
        let m = MockExec::new(64);
        let req = GenRequest::new(vec![10, 11], 24, 64);
        let mut s = FullBaseline.start(&m, &req).unwrap();
        let mut last = s.remaining();
        while let StepOutcome::Running = s.step(&m).unwrap() {
            let now = s.remaining();
            assert!(now < last, "remaining went {last} -> {now}");
            last = now;
        }
    }

    #[test]
    fn plan_cancel_replan_is_deterministic() {
        // cancelling a plan (batched coalescing skipped this session) must
        // leave the machine exactly as before: replanning yields the same
        // forward request and the session completes identically to solo —
        // including for cached plans, which carry the KV cache by value
        use crate::coordinator::Planned;
        use crate::strategies::WindowDiffusion;

        let m = MockExec::new(256);
        let req = GenRequest::new(vec![10, 11, 12, 13], 48, 256);
        let solo = WindowDiffusion::default().generate(&m, &req).unwrap();

        let m2 = MockExec::new(256);
        let mut s = WindowDiffusion::default().start(&m2, &req).unwrap();
        let mut quanta = 0;
        loop {
            // plan, cancel, then replan — both plans must describe the same
            // forward (kind + bucket); then execute the second one
            let first = match s.plan().unwrap() {
                Planned::Forward(p) => p,
                Planned::Finished => break,
            };
            let key = (first.kind(), first.bucket());
            s.cancel_plan(first);
            let second = match s.plan().unwrap() {
                Planned::Forward(p) => p,
                Planned::Finished => panic!("finished after cancel"),
            };
            assert_eq!(key, (second.kind(), second.bucket()), "replan diverged");
            let out = crate::coordinator::execute_plan(&m2, second).unwrap();
            if s.apply(out).unwrap() == StepOutcome::Finished {
                break;
            }
            quanta += 1;
            assert!(quanta < 1000, "runaway session");
        }
        let r = s.into_result();
        assert_eq!(r.generated(), solo.generated(), "cancel/replan changed output");
        assert_eq!(r.steps, solo.steps);
        assert_eq!(r.counts, solo.counts, "cancel/replan changed step accounting");
    }

    #[test]
    fn kv_slot_bytes_matches_arch() {
        let m = MockExec::new(64);
        let a = m.arch();
        // 2 tensors * 4 bytes * L*H*Dh
        assert_eq!(kv_slot_bytes(&a), 2 * 4 * a.n_layers * a.n_heads * a.dh);
    }
}
