//! dKV-Cache baseline [Ma et al. 2025]: cache *decoded* tokens' KV with
//! delayed write and a periodic refresh; masked tokens are always
//! recomputed. Reduces redundant work on decoded context but — as the paper
//! stresses — cannot shorten the masked-token sequence, so its speedup
//! saturates well below window pruning (Table 2: 1.2–2.8×).
//!
//! Implementation on the bucketed executables: the layout is the full live
//! region; every `interval` steps a refresh (`fwd_window`) re-caches
//! everything; in between, `fwd_cached` recomputes all undecoded positions
//! plus tokens decoded since the refresh (delayed cache write), reusing KV
//! for the rest.

use std::time::Instant;

use anyhow::{anyhow, Result};

use super::{commit, Strategy};
use crate::coordinator::policies::{candidates, select_top_k, DecodeSchedule};
use crate::coordinator::{
    ComputeSet, GenRequest, GenResult, SeqState, StepCounts, StepExec, WindowLayout,
};
use crate::runtime::buckets;

pub struct DkvCache {
    /// Refresh interval (paper: 4 on Dream, 8 on LLaDA).
    pub interval: usize,
}

impl Strategy for DkvCache {
    fn name(&self) -> String {
        format!("dkv[i{}]", self.interval)
    }

    fn generate(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<GenResult> {
        assert!(self.interval >= 1);
        let sp = exec.special();
        let vocab = exec.arch().vocab;
        let c_ladder = exec.c_ladder(req.s);
        let r_ladder = exec.r_ladder(req.s);
        let mut state = SeqState::new(&req.prompt, req.gen_len, req.s, sp.mask,
                                      sp.eos, sp.pad)?;
        let schedule = DecodeSchedule::fixed(req.tokens_per_step);
        let mut counts = StepCounts::default();
        let t0 = Instant::now();
        let mut step = 0usize;

        'outer: while !state.done() {
            // (re)build the layout over the live region (shrinks after EOS)
            let positions: Vec<usize> = (0..state.live_end()).collect();
            let layout = WindowLayout::from_positions(&state, positions, &c_ladder)?;
            let live_end = state.live_end();
            let mut kv = None;
            let mut refresh_step = step; // decodes since here are uncached

            while !state.done() {
                if step >= req.step_cap() {
                    return Err(anyhow!("step cap {} exceeded", req.step_cap()));
                }
                if state.live_end() != live_end {
                    continue 'outer; // EOS shrank the region -> rebuild
                }
                let undecoded = state.undecoded();
                let do_refresh = kv.is_none() || (step - refresh_step) >= self.interval;

                let picked = if do_refresh {
                    let (logits, fresh) = exec.window(
                        req.s,
                        layout.c,
                        &layout.ids_padded(&state),
                        &layout.pos_padded(),
                        &layout.cvalid,
                    )?;
                    counts.window += 1;
                    counts.token_slots += layout.c;
                    kv = Some(fresh);
                    refresh_step = step;
                    let cands = candidates(undecoded.iter().map(|&p| {
                        let slot = layout.slot(p).expect("undecoded in layout");
                        (p, &logits[slot * vocab..(slot + 1) * vocab])
                    }));
                    select_top_k(cands, schedule.at(step))
                } else {
                    // compute = undecoded + decoded-after-refresh (delayed write)
                    let recent = state.decoded_since(refresh_step);
                    let cs = match ComputeSet::build(&state, &layout, &undecoded,
                                                     &recent, &r_ladder) {
                        Ok(cs) if buckets::pick(&r_ladder, cs.positions.len()).is_ok()
                            && cs.r <= layout.c =>
                        {
                            cs
                        }
                        _ => {
                            kv = None; // force refresh next iteration
                            continue;
                        }
                    };
                    let cache = kv.as_ref().unwrap();
                    let (logits, new_kv) = exec.cached(
                        req.s, layout.c, cs.r, &cs.ids_r, &cs.pos_r, &cs.slot_idx,
                        &cs.rvalid, &layout.cvalid, cache,
                    )?;
                    counts.cached += 1;
                    counts.token_slots += cs.r;
                    kv = Some(new_kv);
                    let cands = candidates(
                        cs.positions[..cs.n_active]
                            .iter()
                            .copied()
                            .enumerate()
                            .map(|(row, p)| (p, &logits[row * vocab..(row + 1) * vocab])),
                    );
                    select_top_k(cands, schedule.at(step))
                };

                if picked.is_empty() {
                    return Err(anyhow!("no candidates at step {step}"));
                }
                commit(&mut state, &picked, step, req.adaptive)?;
                step += 1;
            }
        }
        Ok(GenResult { state, steps: step, counts, wall: t0.elapsed() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;
    use crate::strategies::FullBaseline;

    #[test]
    fn completes_and_mixes_step_kinds() {
        let m = MockExec::new(256);
        let d = DkvCache { interval: 4 };
        let req = GenRequest::new(vec![10; 8], 64, 256);
        let r = d.generate(&m, &req).unwrap();
        assert!(r.state.done());
        assert!(r.counts.window >= 1);
        assert!(r.counts.cached >= 1);
        // refresh every 4 steps -> roughly steps/4 refreshes
        assert!(r.counts.window <= r.steps / 2 + 1);
    }

    #[test]
    fn cheaper_than_full_but_not_windowed() {
        let req = GenRequest::new(vec![10; 8], 96, 256);
        let rf = FullBaseline.generate(&MockExec::new(256), &req).unwrap();
        let rd = DkvCache { interval: 4 }.generate(&MockExec::new(256), &req).unwrap();
        // saves some compute vs full...
        assert!(rd.counts.token_slots < rf.counts.token_slots);
        // ...but still recomputes all masked tokens: stays within ~3x of full
        assert!(rd.counts.token_slots * 4 > rf.counts.token_slots);
    }

    #[test]
    fn same_output_as_full() {
        // dkv approximates the baseline; with the mock's deterministic
        // logits the decode order/tokens must match exactly
        let req = GenRequest::new(vec![10; 8], 48, 256);
        let rf = FullBaseline.generate(&MockExec::new(256), &req).unwrap();
        let rd = DkvCache { interval: 4 }.generate(&MockExec::new(256), &req).unwrap();
        assert_eq!(rf.generated(), rd.generated());
    }

    #[test]
    fn adaptive_eos() {
        let m = MockExec::new(256).with_eos_at(24);
        let mut req = GenRequest::new(vec![10; 8], 100, 256);
        req.adaptive = true;
        let r = DkvCache { interval: 4 }.generate(&m, &req).unwrap();
        assert_eq!(r.state.eos_pos, Some(24));
        assert!(r.state.done());
    }
}
