//! dKV-Cache baseline [Ma et al. 2025]: cache *decoded* tokens' KV with
//! delayed write and a periodic refresh; masked tokens are always
//! recomputed. Reduces redundant work on decoded context but — as the paper
//! stresses — cannot shorten the masked-token sequence, so its speedup
//! saturates well below window pruning (Table 2: 1.2–2.8×).
//!
//! Implementation on the bucketed executables: the layout is the full live
//! region; every `interval` steps a refresh (`fwd_window`) re-caches
//! everything; in between, `fwd_cached` recomputes all undecoded positions
//! plus tokens decoded since the refresh (delayed cache write), reusing KV
//! for the rest.

use anyhow::{anyhow, Result};

use super::machine::{kv_slot_bytes, Session, SessionCore, StepMachine, StepOutcome};
use super::{commit, Strategy};
use crate::coordinator::policies::{candidates, select_top_k, DecodeSchedule};
use crate::coordinator::{
    ComputeSet, GenRequest, Planned, StepExec, StepOutputs, StepPlan, WindowLayout,
};
use crate::runtime::buckets;
use crate::scheduler::kvstore::KvHandle;

pub struct DkvCache {
    /// Refresh interval (paper: 4 on Dream, 8 on LLaDA).
    pub interval: usize,
}

/// Continuation state: the live-region layout (rebuilt when EOS shrinks it)
/// plus the delayed-write cache and its refresh stamp.
struct DkvState {
    layout: WindowLayout,
    live_end: usize,
    kv: Option<KvHandle>,
    refresh_step: usize, // decodes since here are uncached
}

/// Context carried from `plan` to `apply`.
enum DkvPending {
    /// Refresh over the live layout: decode among all undecoded positions.
    Refresh { undecoded: Vec<usize> },
    /// Normal cached step; the layout KV moved into the plan.
    Normal { cs: ComputeSet },
}

struct DkvMachine {
    interval: usize,
    vocab: usize,
    schedule: DecodeSchedule,
    c_ladder: Vec<usize>,
    r_ladder: Vec<usize>,
    kv_slot_bytes: usize,
    cur: Option<DkvState>,
    pending: Option<DkvPending>,
}

impl StepMachine for DkvMachine {
    fn plan(&mut self, core: &mut SessionCore) -> Result<Planned> {
        debug_assert!(self.pending.is_none(), "plan while a plan is outstanding");
        if core.state.done() {
            return Ok(Planned::Finished);
        }
        core.cap_guard()?;
        // at most one rebuild / forced-refresh retry is ever needed per
        // quantum; 3 attempts is one of safety margin
        for _attempt in 0..3 {
            let rebuild = match &self.cur {
                None => true,
                // EOS shrank the region -> rebuild
                Some(st) => st.live_end != core.state.live_end(),
            };
            if rebuild {
                let positions: Vec<usize> = (0..core.state.live_end()).collect();
                let layout = WindowLayout::from_positions(&core.state, positions, &self.c_ladder)?;
                self.cur = Some(DkvState {
                    layout,
                    live_end: core.state.live_end(),
                    kv: None,
                    refresh_step: core.step,
                });
            }
            let st = self.cur.as_mut().unwrap();
            let undecoded = core.state.undecoded();
            let do_refresh = st.kv.is_none() || (core.step - st.refresh_step) >= self.interval;

            if do_refresh {
                let plan = StepPlan::Window {
                    s: core.req.s,
                    c: st.layout.c,
                    ids: st.layout.ids_padded(&core.state),
                    pos: st.layout.pos_padded(),
                    valid: st.layout.cvalid.clone(),
                };
                self.pending = Some(DkvPending::Refresh { undecoded });
                return Ok(Planned::Forward(plan));
            }
            // compute = undecoded + decoded-after-refresh (delayed write)
            let recent = core.state.decoded_since(st.refresh_step);
            let cs = match ComputeSet::build(&core.state, &st.layout, &undecoded,
                                             &recent, &self.r_ladder) {
                Ok(cs) if buckets::pick(&self.r_ladder, cs.positions.len()).is_ok()
                    && cs.r <= st.layout.c =>
                {
                    cs
                }
                _ => {
                    st.kv = None; // force refresh on the next attempt
                    continue;
                }
            };
            let kv = st.kv.take().unwrap();
            let plan = StepPlan::Cached {
                s: core.req.s,
                c: st.layout.c,
                r: cs.r,
                ids_r: cs.ids_r.clone(),
                pos_r: cs.pos_r.clone(),
                slot_idx: cs.slot_idx.clone(),
                rvalid: cs.rvalid.clone(),
                cvalid: st.layout.cvalid.clone(),
                kv,
            };
            self.pending = Some(DkvPending::Normal { cs });
            return Ok(Planned::Forward(plan));
        }
        Err(anyhow!("dkv made no progress at step {}", core.step))
    }

    fn apply(&mut self, core: &mut SessionCore, out: StepOutputs) -> Result<StepOutcome> {
        let pending = self
            .pending
            .take()
            .ok_or_else(|| anyhow!("apply without an outstanding plan"))?;
        let st = self.cur.as_mut().expect("layout present while a plan is outstanding");
        let picked = match pending {
            DkvPending::Refresh { undecoded } => {
                let StepOutputs::LogitsKv(logits, fresh) = out else {
                    return Err(anyhow!("dkv refresh expects logits + kv"));
                };
                core.counts.window += 1;
                core.counts.token_slots += st.layout.c;
                st.kv = Some(core.adopt_kv(fresh)?);
                st.refresh_step = core.step;
                let cands = candidates(undecoded.iter().map(|&p| {
                    let slot = st.layout.slot(p).expect("undecoded in layout");
                    (p, &logits[slot * self.vocab..(slot + 1) * self.vocab])
                }));
                select_top_k(cands, self.schedule.at(core.step))
            }
            DkvPending::Normal { cs } => {
                let StepOutputs::LogitsKv(logits, new_kv) = out else {
                    return Err(anyhow!("dkv cached step expects logits + kv"));
                };
                core.counts.cached += 1;
                core.counts.token_slots += cs.r;
                st.kv = Some(core.adopt_kv(new_kv)?);
                let cands = candidates(
                    cs.positions[..cs.n_active]
                        .iter()
                        .copied()
                        .enumerate()
                        .map(|(row, p)| (p, &logits[row * self.vocab..(row + 1) * self.vocab])),
                );
                select_top_k(cands, self.schedule.at(core.step))
            }
        };

        if picked.is_empty() {
            return Err(anyhow!("no candidates at step {}", core.step));
        }
        commit(&mut core.state, &picked, core.step, core.req.adaptive)?;
        core.step += 1;
        Ok(if core.state.done() { StepOutcome::Finished } else { StepOutcome::Running })
    }

    fn cancel(&mut self, plan: StepPlan) {
        if let StepPlan::Cached { kv, .. } = plan {
            if let Some(st) = self.cur.as_mut() {
                st.kv = Some(kv);
            }
        }
        self.pending = None;
    }

    fn cache_bytes(&self) -> usize {
        self.cur
            .as_ref()
            .and_then(|st| st.kv.as_ref())
            .map(|kv| kv.c() * self.kv_slot_bytes)
            .unwrap_or(0)
    }

    fn evict_cache(&mut self) {
        // dropping only the KV (not the layout) forces a refresh next step
        if let Some(st) = self.cur.as_mut() {
            st.kv = None;
        }
    }
}

impl Strategy for DkvCache {
    fn name(&self) -> String {
        format!("dkv[i{}]", self.interval)
    }

    fn start(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<Session> {
        assert!(self.interval >= 1);
        let core = SessionCore::new(exec, req)?;
        let machine = DkvMachine {
            interval: self.interval,
            vocab: exec.arch().vocab,
            schedule: DecodeSchedule::fixed(req.tokens_per_step),
            c_ladder: exec.c_ladder(req.s),
            r_ladder: exec.r_ladder(req.s),
            kv_slot_bytes: kv_slot_bytes(&exec.arch()),
            cur: None,
            pending: None,
        };
        Ok(Session::new(self.name(), core, Box::new(machine)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;
    use crate::strategies::FullBaseline;

    #[test]
    fn completes_and_mixes_step_kinds() {
        let m = MockExec::new(256);
        let d = DkvCache { interval: 4 };
        let req = GenRequest::new(vec![10; 8], 64, 256);
        let r = d.generate(&m, &req).unwrap();
        assert!(r.state.done());
        assert!(r.counts.window >= 1);
        assert!(r.counts.cached >= 1);
        // refresh every 4 steps -> roughly steps/4 refreshes
        assert!(r.counts.window <= r.steps / 2 + 1);
    }

    #[test]
    fn cheaper_than_full_but_not_windowed() {
        let req = GenRequest::new(vec![10; 8], 96, 256);
        let rf = FullBaseline.generate(&MockExec::new(256), &req).unwrap();
        let rd = DkvCache { interval: 4 }.generate(&MockExec::new(256), &req).unwrap();
        // saves some compute vs full...
        assert!(rd.counts.token_slots < rf.counts.token_slots);
        // ...but still recomputes all masked tokens: stays within ~3x of full
        assert!(rd.counts.token_slots * 4 > rf.counts.token_slots);
    }

    #[test]
    fn same_output_as_full() {
        // dkv approximates the baseline; with the mock's deterministic
        // logits the decode order/tokens must match exactly
        let req = GenRequest::new(vec![10; 8], 48, 256);
        let rf = FullBaseline.generate(&MockExec::new(256), &req).unwrap();
        let rd = DkvCache { interval: 4 }.generate(&MockExec::new(256), &req).unwrap();
        assert_eq!(rf.generated(), rd.generated());
    }

    #[test]
    fn adaptive_eos() {
        let m = MockExec::new(256).with_eos_at(24);
        let mut req = GenRequest::new(vec![10; 8], 100, 256);
        req.adaptive = true;
        let r = DkvCache { interval: 4 }.generate(&m, &req).unwrap();
        assert_eq!(r.state.eos_pos, Some(24));
        assert!(r.state.done());
    }
}
