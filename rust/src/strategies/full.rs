//! Full-sequence baseline: the original DLM inference paradigm.
//!
//! Every diffusion step runs a forward pass over the whole sequence
//! (`O(T · L · S²)`), computes confidence for every undecoded position, and
//! commits the top-k. This is the "Dream"/"LLaDA" row of Tables 2/3/6 and
//! the reference all speedups are measured against.

use anyhow::{anyhow, Result};

use super::machine::{Session, SessionCore, StepMachine, StepOutcome};
use super::{commit, Strategy};
use crate::coordinator::policies::{candidates, select_top_k, DecodeSchedule};
use crate::coordinator::{GenRequest, Planned, StepExec, StepOutputs, StepPlan};

pub struct FullBaseline;

/// Stateless between steps: every quantum is one full-sequence forward.
struct FullMachine {
    vocab: usize,
    schedule: DecodeSchedule,
}

impl StepMachine for FullMachine {
    fn plan(&mut self, core: &mut SessionCore) -> Result<Planned> {
        if core.state.done() {
            return Ok(Planned::Finished);
        }
        core.cap_guard()?;
        Ok(Planned::Forward(StepPlan::Full {
            s: core.req.s,
            ids: core.state.ids.clone(),
            valid: core.state.full_valid(),
        }))
    }

    fn apply(&mut self, core: &mut SessionCore, out: StepOutputs) -> Result<StepOutcome> {
        let logits = out.logits();
        core.counts.full += 1;
        core.counts.token_slots += core.req.s;
        let undecoded = core.state.undecoded();
        let cands = candidates(
            undecoded.iter().map(|&p| (p, &logits[p * self.vocab..(p + 1) * self.vocab])),
        );
        let picked = select_top_k(cands, self.schedule.at(core.step));
        if picked.is_empty() {
            return Err(anyhow!("no candidates at step {}", core.step));
        }
        commit(&mut core.state, &picked, core.step, core.req.adaptive)?;
        core.step += 1;
        Ok(if core.state.done() { StepOutcome::Finished } else { StepOutcome::Running })
    }
}

impl Strategy for FullBaseline {
    fn name(&self) -> String {
        "full".into()
    }

    fn start(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<Session> {
        let core = SessionCore::new(exec, req)?;
        let machine = FullMachine {
            vocab: exec.arch().vocab,
            schedule: DecodeSchedule::fixed(req.tokens_per_step),
        };
        Ok(Session::new(self.name(), core, Box::new(machine)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;

    #[test]
    fn decodes_everything() {
        let m = MockExec::new(64);
        let req = GenRequest::new(vec![10, 11, 12, 13], 32, 64);
        let r = FullBaseline.generate(&m, &req).unwrap();
        assert!(r.state.done());
        assert_eq!(r.tokens_generated(), 32);
        // 2 tokens per step -> 16 steps
        assert_eq!(r.steps, 16);
        assert_eq!(r.counts.full, 16);
        assert_eq!(r.counts.token_slots, 16 * 64);
        // mock decodes its deterministic tokens
        let gen = r.generated();
        assert_eq!(gen[0], m.token_at(4));
    }

    #[test]
    fn adaptive_stops_at_eos() {
        let m = MockExec::new(64).with_eos_at(12);
        let mut req = GenRequest::new(vec![10, 11, 12, 13], 40, 64);
        req.adaptive = true;
        let r = FullBaseline.generate(&m, &req).unwrap();
        assert!(r.state.done());
        assert_eq!(r.state.eos_pos, Some(12));
        // generated = positions 4..12 (eos stripped)
        assert_eq!(r.tokens_generated(), 8);
        // far fewer steps than the static 20
        assert!(r.steps <= 6, "steps {}", r.steps);
    }

    #[test]
    fn mock_prefix_locality_decodes_front_first() {
        let m = MockExec::new(64);
        let mut req = GenRequest::new(vec![10, 11], 20, 64);
        req.tokens_per_step = 1;
        let r = FullBaseline.generate(&m, &req).unwrap();
        // with monotonically decaying confidence the decode order is L->R
        let at = |p: usize| r.state.decoded_at[p].unwrap();
        assert!(at(2) < at(3) && at(3) < at(4));
    }
}
