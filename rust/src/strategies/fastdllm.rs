//! Fast-dLLM baselines [Wu et al. 2025] (parallel decoding disabled, as in
//! the paper's comparison setup).
//!
//! **Prefix-Cache**: block-wise decoding; the decoded prefix's KV is cached
//! at each block boundary, but the current block *and every masked token
//! after it* are recomputed at every step — masked-token cost remains.
//!
//! **Dual-Cache**: additionally caches the masked *suffix* KV at the block
//! boundary, recomputing only the current block each step. Faster, but the
//! stale suffix representations cost accuracy (Table 2: HumanEval-Instruct
//! drops to 23.8) and the block-boundary refresh still touches the full
//! sequence.

use anyhow::{anyhow, Result};

use super::machine::{kv_slot_bytes, Session, SessionCore, StepMachine, StepOutcome};
use super::{commit, Strategy};
use crate::coordinator::policies::{candidates, select_top_k, DecodeSchedule};
use crate::coordinator::{
    ComputeSet, GenRequest, Planned, StepExec, StepOutputs, StepPlan, WindowLayout,
};
use crate::runtime::buckets;
use crate::scheduler::kvstore::KvHandle;

pub struct FastDllmPrefix {
    pub block: usize,
}

pub struct FastDllmDual {
    pub block: usize,
}

/// Continuation state between a block-boundary refresh and the block's
/// normal steps. Dropped (forcing a fresh refresh) when the block completes,
/// the live region shrinks, or the compute set overflows the buckets.
/// `kv` is `None` only while a cached plan is in flight (the cache travels
/// inside the plan).
struct FdPhase {
    block_start: usize,
    block_end: usize,
    live_end: usize,
    layout: WindowLayout,
    kv: Option<KvHandle>,
    block_decoded: Vec<usize>,
}

/// Context carried from `plan` to `apply`.
enum FdPending {
    /// Block-boundary refresh; `apply` installs the new phase.
    Refresh {
        block_start: usize,
        block_end: usize,
        live_end: usize,
        layout: WindowLayout,
    },
    /// Normal in-block step; the first `n_block` compute positions are the
    /// block's undecoded set (decode selection is restricted to them).
    Normal { cs: ComputeSet, n_block: usize },
}

/// Shared block-walk machine; `dual` selects the compute-set rule.
struct FastDllmMachine {
    block: usize,
    dual: bool,
    vocab: usize,
    schedule: DecodeSchedule,
    c_ladder: Vec<usize>,
    r_ladder: Vec<usize>,
    kv_slot_bytes: usize,
    phase: Option<FdPhase>,
    pending: Option<FdPending>,
}

impl StepMachine for FastDllmMachine {
    fn plan(&mut self, core: &mut SessionCore) -> Result<Planned> {
        debug_assert!(self.pending.is_none(), "plan while a plan is outstanding");
        if core.state.done() {
            return Ok(Planned::Finished);
        }
        core.cap_guard()?;
        // a dropped phase resolves to a refresh plan; two attempts suffice,
        // 3 is one of safety margin
        for _attempt in 0..3 {
            let stale = match &self.phase {
                None => true,
                Some(ph) => {
                    let block_done = !core
                        .state
                        .undecoded()
                        .iter()
                        .any(|&p| p >= ph.block_start && p < ph.block_end);
                    // EOS shrank the region -> rebuild at a fresh boundary
                    block_done || core.state.live_end() != ph.live_end
                }
            };
            if stale {
                self.phase = None;
                // block-boundary refresh over the whole live sequence
                let frontier = core.state.frontier().expect("not done");
                let block_start = core.state.prompt_len
                    + ((frontier - core.state.prompt_len) / self.block) * self.block;
                let live_end = core.state.live_end();
                let block_end = (block_start + self.block).min(live_end);
                let positions: Vec<usize> = (0..live_end).collect();
                let layout =
                    WindowLayout::from_positions(&core.state, positions, &self.c_ladder)?;
                let plan = StepPlan::Window {
                    s: core.req.s,
                    c: layout.c,
                    ids: layout.ids_padded(&core.state),
                    pos: layout.pos_padded(),
                    valid: layout.cvalid.clone(),
                };
                self.pending =
                    Some(FdPending::Refresh { block_start, block_end, live_end, layout });
                return Ok(Planned::Forward(plan));
            }
            // -- normal step within the current block ------------------------
            let ph = self.phase.as_mut().unwrap();
            let in_block = |p: &usize| *p >= ph.block_start && *p < ph.block_end;
            let block_undecoded: Vec<usize> =
                core.state.undecoded().into_iter().filter(in_block).collect();
            // compute set:
            //   prefix-cache: block ∪ all masked suffix (+ in-block decodes)
            //   dual-cache:   block only (+ in-block decodes)
            let mut active = block_undecoded.clone();
            if !self.dual {
                active.extend(
                    core.state.undecoded().into_iter().filter(|&p| p >= ph.block_end),
                );
            }
            let cs = match ComputeSet::build(&core.state, &ph.layout, &active,
                                             &ph.block_decoded, &self.r_ladder) {
                Ok(cs) if cs.r <= ph.layout.c
                    && buckets::pick(&self.r_ladder, cs.positions.len()).is_ok() =>
                {
                    cs
                }
                _ => {
                    // overflow -> fall back to a fresh block refresh
                    self.phase = None;
                    continue;
                }
            };
            let kv = ph.kv.take().expect("refresh precedes normal steps");
            let plan = StepPlan::Cached {
                s: core.req.s,
                c: ph.layout.c,
                r: cs.r,
                ids_r: cs.ids_r.clone(),
                pos_r: cs.pos_r.clone(),
                slot_idx: cs.slot_idx.clone(),
                rvalid: cs.rvalid.clone(),
                cvalid: ph.layout.cvalid.clone(),
                kv,
            };
            self.pending = Some(FdPending::Normal { cs, n_block: block_undecoded.len() });
            return Ok(Planned::Forward(plan));
        }
        Err(anyhow!("fastdllm made no progress at step {}", core.step))
    }

    fn apply(&mut self, core: &mut SessionCore, out: StepOutputs) -> Result<StepOutcome> {
        let pending = self
            .pending
            .take()
            .ok_or_else(|| anyhow!("apply without an outstanding plan"))?;
        match pending {
            FdPending::Refresh { block_start, block_end, live_end, layout } => {
                let StepOutputs::LogitsKv(logits, kv) = out else {
                    return Err(anyhow!("fastdllm refresh expects logits + kv"));
                };
                core.counts.window += 1;
                core.counts.token_slots += layout.c;
                let block_cands: Vec<usize> = core
                    .state
                    .undecoded()
                    .into_iter()
                    .filter(|&p| p >= block_start && p < block_end)
                    .collect();
                let cands = candidates(block_cands.iter().map(|&p| {
                    let slot = layout.slot(p).expect("in layout");
                    (p, &logits[slot * self.vocab..(slot + 1) * self.vocab])
                }));
                let picked = select_top_k(cands, self.schedule.at(core.step));
                if picked.is_empty() {
                    return Err(anyhow!("no candidates at refresh step {}", core.step));
                }
                commit(&mut core.state, &picked, core.step, core.req.adaptive)?;
                let block_decoded: Vec<usize> = picked.iter().map(|c| c.pos).collect();
                core.step += 1;
                self.phase = Some(FdPhase {
                    block_start,
                    block_end,
                    live_end,
                    layout,
                    kv: Some(core.adopt_kv(kv)?),
                    block_decoded,
                });
            }
            FdPending::Normal { cs, n_block } => {
                let StepOutputs::LogitsKv(logits, new_kv) = out else {
                    return Err(anyhow!("fastdllm cached step expects logits + kv"));
                };
                let ph = self.phase.as_mut().expect("phase present for a normal step");
                core.counts.cached += 1;
                core.counts.token_slots += cs.r;
                ph.kv = Some(core.adopt_kv(new_kv)?);
                // decode only within the block (block_undecoded is a prefix
                // of the compute positions by construction)
                let cands = candidates(
                    cs.positions[..n_block]
                        .iter()
                        .copied()
                        .enumerate()
                        .map(|(row, p)| (p, &logits[row * self.vocab..(row + 1) * self.vocab])),
                );
                let picked = select_top_k(cands, self.schedule.at(core.step));
                if picked.is_empty() {
                    return Err(anyhow!("no block candidates at step {}", core.step));
                }
                commit(&mut core.state, &picked, core.step, core.req.adaptive)?;
                ph.block_decoded.extend(picked.iter().map(|c| c.pos));
                core.step += 1;
            }
        }
        Ok(if core.state.done() { StepOutcome::Finished } else { StepOutcome::Running })
    }

    fn cancel(&mut self, plan: StepPlan) {
        if let StepPlan::Cached { kv, .. } = plan {
            if let Some(ph) = self.phase.as_mut() {
                ph.kv = Some(kv);
            }
        }
        self.pending = None;
    }

    fn cache_bytes(&self) -> usize {
        self.phase
            .as_ref()
            .and_then(|ph| ph.kv.as_ref())
            .map(|kv| kv.c() * self.kv_slot_bytes)
            .unwrap_or(0)
    }

    fn evict_cache(&mut self) {
        // dropping the phase forces a block-boundary refresh next step
        self.phase = None;
    }
}

fn start_blockwise(exec: &dyn StepExec, req: &GenRequest, name: String, block: usize,
                   dual: bool) -> Result<Session> {
    assert!(block >= 1);
    let core = SessionCore::new(exec, req)?;
    let machine = FastDllmMachine {
        block,
        dual,
        vocab: exec.arch().vocab,
        schedule: DecodeSchedule::fixed(req.tokens_per_step),
        c_ladder: exec.c_ladder(req.s),
        r_ladder: exec.r_ladder(req.s),
        kv_slot_bytes: kv_slot_bytes(&exec.arch()),
        phase: None,
        pending: None,
    };
    Ok(Session::new(name, core, Box::new(machine)))
}

impl Strategy for FastDllmPrefix {
    fn name(&self) -> String {
        format!("fastdllm-prefix[b{}]", self.block)
    }
    fn start(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<Session> {
        start_blockwise(exec, req, self.name(), self.block, false)
    }
}

impl Strategy for FastDllmDual {
    fn name(&self) -> String {
        format!("fastdllm-dual[b{}]", self.block)
    }
    fn start(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<Session> {
        start_blockwise(exec, req, self.name(), self.block, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;
    use crate::strategies::FullBaseline;

    fn req(gen: usize) -> GenRequest {
        GenRequest::new(vec![10; 8], gen, 256)
    }

    #[test]
    fn prefix_completes() {
        let r = FastDllmPrefix { block: 32 }
            .generate(&MockExec::new(256), &req(96))
            .unwrap();
        assert!(r.state.done());
        assert!(r.counts.window >= 3); // one refresh per block
        assert!(r.counts.cached > 0);
    }

    #[test]
    fn dual_cheaper_than_prefix() {
        let rp = FastDllmPrefix { block: 32 }
            .generate(&MockExec::new(256), &req(96))
            .unwrap();
        let rd = FastDllmDual { block: 32 }
            .generate(&MockExec::new(256), &req(96))
            .unwrap();
        assert!(rd.counts.token_slots < rp.counts.token_slots,
                "dual {} vs prefix {}", rd.counts.token_slots, rp.counts.token_slots);
    }

    #[test]
    fn both_match_full_output_under_mock() {
        let rf = FullBaseline.generate(&MockExec::new(256), &req(64)).unwrap();
        let rp = FastDllmPrefix { block: 32 }
            .generate(&MockExec::new(256), &req(64))
            .unwrap();
        let rd = FastDllmDual { block: 32 }
            .generate(&MockExec::new(256), &req(64))
            .unwrap();
        assert_eq!(rf.generated(), rp.generated());
        assert_eq!(rf.generated(), rd.generated());
    }

    #[test]
    fn adaptive_eos() {
        let m = MockExec::new(256).with_eos_at(30);
        let mut rq = req(128);
        rq.adaptive = true;
        let r = FastDllmDual { block: 32 }.generate(&m, &rq).unwrap();
        assert_eq!(r.state.eos_pos, Some(30));
        assert!(r.state.done());
    }
}
