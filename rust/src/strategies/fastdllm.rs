//! Fast-dLLM baselines [Wu et al. 2025] (parallel decoding disabled, as in
//! the paper's comparison setup).
//!
//! **Prefix-Cache**: block-wise decoding; the decoded prefix's KV is cached
//! at each block boundary, but the current block *and every masked token
//! after it* are recomputed at every step — masked-token cost remains.
//!
//! **Dual-Cache**: additionally caches the masked *suffix* KV at the block
//! boundary, recomputing only the current block each step. Faster, but the
//! stale suffix representations cost accuracy (Table 2: HumanEval-Instruct
//! drops to 23.8) and the block-boundary refresh still touches the full
//! sequence.

use std::time::Instant;

use anyhow::{anyhow, Result};

use super::{commit, Strategy};
use crate::coordinator::policies::{candidates, select_top_k, DecodeSchedule};
use crate::coordinator::{
    ComputeSet, GenRequest, GenResult, SeqState, StepCounts, StepExec, WindowLayout,
};
use crate::runtime::buckets;

pub struct FastDllmPrefix {
    pub block: usize,
}

pub struct FastDllmDual {
    pub block: usize,
}

/// Shared block-walk skeleton; `dual` selects the compute-set rule.
fn generate_blockwise(exec: &dyn StepExec, req: &GenRequest, block: usize,
                      dual: bool) -> Result<GenResult> {
    assert!(block >= 1);
    let sp = exec.special();
    let vocab = exec.arch().vocab;
    let c_ladder = exec.c_ladder(req.s);
    let r_ladder = exec.r_ladder(req.s);
    let mut state = SeqState::new(&req.prompt, req.gen_len, req.s, sp.mask,
                                  sp.eos, sp.pad)?;
    let schedule = DecodeSchedule::fixed(req.tokens_per_step);
    let mut counts = StepCounts::default();
    let t0 = Instant::now();
    let mut step = 0usize;

    while !state.done() {
        if step >= req.step_cap() {
            return Err(anyhow!("step cap {} exceeded", req.step_cap()));
        }
        let frontier = state.frontier().expect("not done");
        let block_start = state.prompt_len
            + ((frontier - state.prompt_len) / block) * block;
        let block_end = (block_start + block).min(state.live_end());
        let live_end = state.live_end();

        // -- block-boundary refresh over the whole live sequence ------------
        let positions: Vec<usize> = (0..live_end).collect();
        let layout = WindowLayout::from_positions(&state, positions, &c_ladder)?;
        let (logits, mut kv) = exec.window(
            req.s,
            layout.c,
            &layout.ids_padded(&state),
            &layout.pos_padded(),
            &layout.cvalid,
        )?;
        counts.window += 1;
        counts.token_slots += layout.c;
        let in_block = |p: &usize| *p >= block_start && *p < block_end;
        let block_cands: Vec<usize> =
            state.undecoded().into_iter().filter(in_block).collect();
        let cands = candidates(block_cands.iter().map(|&p| {
            let slot = layout.slot(p).expect("in layout");
            (p, &logits[slot * vocab..(slot + 1) * vocab])
        }));
        let picked = select_top_k(cands, schedule.at(step));
        if picked.is_empty() {
            return Err(anyhow!("no candidates at refresh step {step}"));
        }
        commit(&mut state, &picked, step, req.adaptive)?;
        let mut block_decoded: Vec<usize> = picked.iter().map(|c| c.pos).collect();
        step += 1;

        // -- normal steps until the block is fully decoded -------------------
        while state.undecoded().iter().any(in_block) {
            if step >= req.step_cap() {
                return Err(anyhow!("step cap {} exceeded", req.step_cap()));
            }
            if state.live_end() != live_end {
                break; // EOS shrank the region; rebuild at next block loop
            }
            let block_undecoded: Vec<usize> =
                state.undecoded().into_iter().filter(in_block).collect();
            // compute set:
            //   prefix-cache: block ∪ all masked suffix (+ in-block decodes)
            //   dual-cache:   block only (+ in-block decodes)
            let mut active = block_undecoded.clone();
            if !dual {
                active.extend(state.undecoded().into_iter().filter(|&p| p >= block_end));
            }
            let cs = match ComputeSet::build(&state, &layout, &active,
                                             &block_decoded, &r_ladder) {
                Ok(cs) if cs.r <= layout.c
                    && buckets::pick(&r_ladder, cs.positions.len()).is_ok() =>
                {
                    cs
                }
                _ => break, // overflow -> fall back to a fresh block refresh
            };
            let (logits, new_kv) = exec.cached(
                req.s, layout.c, cs.r, &cs.ids_r, &cs.pos_r, &cs.slot_idx,
                &cs.rvalid, &layout.cvalid, &kv,
            )?;
            counts.cached += 1;
            counts.token_slots += cs.r;
            kv = new_kv;
            // decode only within the block (block_undecoded is a prefix of
            // the compute positions by construction)
            let cands = candidates(
                cs.positions[..block_undecoded.len()]
                    .iter()
                    .copied()
                    .enumerate()
                    .map(|(row, p)| (p, &logits[row * vocab..(row + 1) * vocab])),
            );
            let picked = select_top_k(cands, schedule.at(step));
            if picked.is_empty() {
                return Err(anyhow!("no block candidates at step {step}"));
            }
            commit(&mut state, &picked, step, req.adaptive)?;
            block_decoded.extend(picked.iter().map(|c| c.pos));
            step += 1;
        }
    }
    Ok(GenResult { state, steps: step, counts, wall: t0.elapsed() })
}

impl Strategy for FastDllmPrefix {
    fn name(&self) -> String {
        format!("fastdllm-prefix[b{}]", self.block)
    }
    fn generate(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<GenResult> {
        generate_blockwise(exec, req, self.block, false)
    }
}

impl Strategy for FastDllmDual {
    fn name(&self) -> String {
        format!("fastdllm-dual[b{}]", self.block)
    }
    fn generate(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<GenResult> {
        generate_blockwise(exec, req, self.block, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;
    use crate::strategies::FullBaseline;

    fn req(gen: usize) -> GenRequest {
        GenRequest::new(vec![10; 8], gen, 256)
    }

    #[test]
    fn prefix_completes() {
        let r = FastDllmPrefix { block: 32 }
            .generate(&MockExec::new(256), &req(96))
            .unwrap();
        assert!(r.state.done());
        assert!(r.counts.window >= 3); // one refresh per block
        assert!(r.counts.cached > 0);
    }

    #[test]
    fn dual_cheaper_than_prefix() {
        let rp = FastDllmPrefix { block: 32 }
            .generate(&MockExec::new(256), &req(96))
            .unwrap();
        let rd = FastDllmDual { block: 32 }
            .generate(&MockExec::new(256), &req(96))
            .unwrap();
        assert!(rd.counts.token_slots < rp.counts.token_slots,
                "dual {} vs prefix {}", rd.counts.token_slots, rp.counts.token_slots);
    }

    #[test]
    fn both_match_full_output_under_mock() {
        let rf = FullBaseline.generate(&MockExec::new(256), &req(64)).unwrap();
        let rp = FastDllmPrefix { block: 32 }
            .generate(&MockExec::new(256), &req(64))
            .unwrap();
        let rd = FastDllmDual { block: 32 }
            .generate(&MockExec::new(256), &req(64))
            .unwrap();
        assert_eq!(rf.generated(), rp.generated());
        assert_eq!(rf.generated(), rd.generated());
    }

    #[test]
    fn adaptive_eos() {
        let m = MockExec::new(256).with_eos_at(30);
        let mut rq = req(128);
        rq.adaptive = true;
        let r = FastDllmDual { block: 32 }.generate(&m, &rq).unwrap();
        assert_eq!(r.state.eos_pos, Some(30));
        assert!(r.state.done());
    }
}
