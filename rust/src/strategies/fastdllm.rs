//! Fast-dLLM baselines [Wu et al. 2025] (parallel decoding disabled, as in
//! the paper's comparison setup).
//!
//! **Prefix-Cache**: block-wise decoding; the decoded prefix's KV is cached
//! at each block boundary, but the current block *and every masked token
//! after it* are recomputed at every step — masked-token cost remains.
//!
//! **Dual-Cache**: additionally caches the masked *suffix* KV at the block
//! boundary, recomputing only the current block each step. Faster, but the
//! stale suffix representations cost accuracy (Table 2: HumanEval-Instruct
//! drops to 23.8) and the block-boundary refresh still touches the full
//! sequence.

use anyhow::{anyhow, Result};

use super::machine::{kv_slot_bytes, Session, SessionCore, StepMachine, StepOutcome};
use super::{commit, Strategy};
use crate::coordinator::policies::{candidates, select_top_k, DecodeSchedule};
use crate::coordinator::{ComputeSet, GenRequest, StepExec, WindowLayout};
use crate::runtime::{buckets, KvCache};

pub struct FastDllmPrefix {
    pub block: usize,
}

pub struct FastDllmDual {
    pub block: usize,
}

/// Continuation state between a block-boundary refresh and the block's
/// normal steps. Dropped (forcing a fresh refresh) when the block completes,
/// the live region shrinks, or the compute set overflows the buckets.
struct FdPhase {
    block_start: usize,
    block_end: usize,
    live_end: usize,
    layout: WindowLayout,
    kv: KvCache,
    block_decoded: Vec<usize>,
}

/// Shared block-walk machine; `dual` selects the compute-set rule.
struct FastDllmMachine {
    block: usize,
    dual: bool,
    vocab: usize,
    schedule: DecodeSchedule,
    c_ladder: Vec<usize>,
    r_ladder: Vec<usize>,
    kv_slot_bytes: usize,
    phase: Option<FdPhase>,
}

impl FastDllmMachine {
    /// Block-boundary refresh over the whole live sequence: one committed
    /// step, then the new phase is installed.
    fn refresh_step(&mut self, core: &mut SessionCore, exec: &dyn StepExec)
                    -> Result<StepOutcome> {
        let frontier = core.state.frontier().expect("not done");
        let block_start = core.state.prompt_len
            + ((frontier - core.state.prompt_len) / self.block) * self.block;
        let live_end = core.state.live_end();
        let block_end = (block_start + self.block).min(live_end);
        let positions: Vec<usize> = (0..live_end).collect();
        let layout = WindowLayout::from_positions(&core.state, positions, &self.c_ladder)?;
        let (logits, kv) = exec.window(
            core.req.s,
            layout.c,
            &layout.ids_padded(&core.state),
            &layout.pos_padded(),
            &layout.cvalid,
        )?;
        core.counts.window += 1;
        core.counts.token_slots += layout.c;
        let block_cands: Vec<usize> = core
            .state
            .undecoded()
            .into_iter()
            .filter(|&p| p >= block_start && p < block_end)
            .collect();
        let cands = candidates(block_cands.iter().map(|&p| {
            let slot = layout.slot(p).expect("in layout");
            (p, &logits[slot * self.vocab..(slot + 1) * self.vocab])
        }));
        let picked = select_top_k(cands, self.schedule.at(core.step));
        if picked.is_empty() {
            return Err(anyhow!("no candidates at refresh step {}", core.step));
        }
        commit(&mut core.state, &picked, core.step, core.req.adaptive)?;
        let block_decoded: Vec<usize> = picked.iter().map(|c| c.pos).collect();
        core.step += 1;
        self.phase = Some(FdPhase {
            block_start,
            block_end,
            live_end,
            layout,
            kv,
            block_decoded,
        });
        Ok(if core.state.done() { StepOutcome::Finished } else { StepOutcome::Running })
    }
}

impl StepMachine for FastDllmMachine {
    fn step(&mut self, core: &mut SessionCore, exec: &dyn StepExec) -> Result<StepOutcome> {
        if core.state.done() {
            return Ok(StepOutcome::Finished);
        }
        core.cap_guard()?;
        // a dropped phase resolves to a refresh, which always commits; two
        // attempts suffice, 3 is one of safety margin
        for _attempt in 0..3 {
            let stale = match &self.phase {
                None => true,
                Some(ph) => {
                    let block_done = !core
                        .state
                        .undecoded()
                        .iter()
                        .any(|&p| p >= ph.block_start && p < ph.block_end);
                    // EOS shrank the region -> rebuild at a fresh boundary
                    block_done || core.state.live_end() != ph.live_end
                }
            };
            if stale {
                self.phase = None;
                return self.refresh_step(core, exec);
            }
            // -- normal step within the current block ------------------------
            let ph = self.phase.as_mut().unwrap();
            let in_block = |p: &usize| *p >= ph.block_start && *p < ph.block_end;
            let block_undecoded: Vec<usize> =
                core.state.undecoded().into_iter().filter(in_block).collect();
            // compute set:
            //   prefix-cache: block ∪ all masked suffix (+ in-block decodes)
            //   dual-cache:   block only (+ in-block decodes)
            let mut active = block_undecoded.clone();
            if !self.dual {
                active.extend(
                    core.state.undecoded().into_iter().filter(|&p| p >= ph.block_end),
                );
            }
            let cs = match ComputeSet::build(&core.state, &ph.layout, &active,
                                             &ph.block_decoded, &self.r_ladder) {
                Ok(cs) if cs.r <= ph.layout.c
                    && buckets::pick(&self.r_ladder, cs.positions.len()).is_ok() =>
                {
                    cs
                }
                _ => {
                    // overflow -> fall back to a fresh block refresh
                    self.phase = None;
                    continue;
                }
            };
            let (logits, new_kv) = exec.cached(
                core.req.s, ph.layout.c, cs.r, &cs.ids_r, &cs.pos_r, &cs.slot_idx,
                &cs.rvalid, &ph.layout.cvalid, &ph.kv,
            )?;
            core.counts.cached += 1;
            core.counts.token_slots += cs.r;
            ph.kv = new_kv;
            // decode only within the block (block_undecoded is a prefix of
            // the compute positions by construction)
            let cands = candidates(
                cs.positions[..block_undecoded.len()]
                    .iter()
                    .copied()
                    .enumerate()
                    .map(|(row, p)| (p, &logits[row * self.vocab..(row + 1) * self.vocab])),
            );
            let picked = select_top_k(cands, self.schedule.at(core.step));
            if picked.is_empty() {
                return Err(anyhow!("no block candidates at step {}", core.step));
            }
            commit(&mut core.state, &picked, core.step, core.req.adaptive)?;
            ph.block_decoded.extend(picked.iter().map(|c| c.pos));
            core.step += 1;
            return Ok(if core.state.done() { StepOutcome::Finished } else { StepOutcome::Running });
        }
        Err(anyhow!("fastdllm made no progress at step {}", core.step))
    }

    fn cache_bytes(&self) -> usize {
        self.phase
            .as_ref()
            .map(|ph| ph.kv.c * self.kv_slot_bytes)
            .unwrap_or(0)
    }

    fn evict_cache(&mut self) {
        // dropping the phase forces a block-boundary refresh next step
        self.phase = None;
    }
}

fn start_blockwise(exec: &dyn StepExec, req: &GenRequest, name: String, block: usize,
                   dual: bool) -> Result<Session> {
    assert!(block >= 1);
    let core = SessionCore::new(exec, req)?;
    let machine = FastDllmMachine {
        block,
        dual,
        vocab: exec.arch().vocab,
        schedule: DecodeSchedule::fixed(req.tokens_per_step),
        c_ladder: exec.c_ladder(req.s),
        r_ladder: exec.r_ladder(req.s),
        kv_slot_bytes: kv_slot_bytes(&exec.arch()),
        phase: None,
    };
    Ok(Session::new(name, core, Box::new(machine)))
}

impl Strategy for FastDllmPrefix {
    fn name(&self) -> String {
        format!("fastdllm-prefix[b{}]", self.block)
    }
    fn start(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<Session> {
        start_blockwise(exec, req, self.name(), self.block, false)
    }
}

impl Strategy for FastDllmDual {
    fn name(&self) -> String {
        format!("fastdllm-dual[b{}]", self.block)
    }
    fn start(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<Session> {
        start_blockwise(exec, req, self.name(), self.block, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;
    use crate::strategies::FullBaseline;

    fn req(gen: usize) -> GenRequest {
        GenRequest::new(vec![10; 8], gen, 256)
    }

    #[test]
    fn prefix_completes() {
        let r = FastDllmPrefix { block: 32 }
            .generate(&MockExec::new(256), &req(96))
            .unwrap();
        assert!(r.state.done());
        assert!(r.counts.window >= 3); // one refresh per block
        assert!(r.counts.cached > 0);
    }

    #[test]
    fn dual_cheaper_than_prefix() {
        let rp = FastDllmPrefix { block: 32 }
            .generate(&MockExec::new(256), &req(96))
            .unwrap();
        let rd = FastDllmDual { block: 32 }
            .generate(&MockExec::new(256), &req(96))
            .unwrap();
        assert!(rd.counts.token_slots < rp.counts.token_slots,
                "dual {} vs prefix {}", rd.counts.token_slots, rp.counts.token_slots);
    }

    #[test]
    fn both_match_full_output_under_mock() {
        let rf = FullBaseline.generate(&MockExec::new(256), &req(64)).unwrap();
        let rp = FastDllmPrefix { block: 32 }
            .generate(&MockExec::new(256), &req(64))
            .unwrap();
        let rd = FastDllmDual { block: 32 }
            .generate(&MockExec::new(256), &req(64))
            .unwrap();
        assert_eq!(rf.generated(), rp.generated());
        assert_eq!(rf.generated(), rd.generated());
    }

    #[test]
    fn adaptive_eos() {
        let m = MockExec::new(256).with_eos_at(30);
        let mut rq = req(128);
        rq.adaptive = true;
        let r = FastDllmDual { block: 32 }.generate(&m, &rq).unwrap();
        assert_eq!(r.state.eos_pos, Some(30));
        assert!(r.state.done());
    }
}
