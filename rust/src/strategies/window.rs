//! **Window-Diffusion** — the paper's method (§4).
//!
//! Denoising is partitioned into phases. Each phase:
//!
//! 1. builds the window layout (all decoded tokens ∥ external window of the
//!    first `w_ex` undecoded positions; far-field pruned),
//! 2. runs one **refresh step**: a full forward over the layout
//!    (`fwd_window`), writing every slot's K/V into the phase cache,
//! 3. runs **normal steps** until the refresh cycle elapses: only the active
//!    tokens (internal window, first `a` undecoded) plus tokens decoded
//!    earlier in the phase are recomputed (`fwd_cached`); buffer tokens and
//!    pre-phase decoded tokens are served from the cache,
//! 4. decodes top-confidence actives each step; the internal window slides
//!    right as tokens decode.
//!
//! `cache: false` gives the pruning-only ablation of Table 1: the layout is
//! rebuilt and fully recomputed every step (phase length 1, no reuse).
//!
//! A phase also ends early when the internal window escapes the layout
//! (every external-window slot decoded) or the compute set outgrows the `r`
//! buckets that fit the cached window.

use std::time::Instant;

use anyhow::{anyhow, Result};

use super::{commit, Strategy};
use crate::coordinator::policies::{candidates, select_top_k, DecodeSchedule};
use crate::coordinator::{
    ComputeSet, GenRequest, GenResult, SeqState, StepCounts, StepExec, WindowLayout,
};
use crate::runtime::buckets;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WdConfig {
    /// External window length (undecoded prefix retained as context).
    pub w_ex: usize,
    /// Internal window length (active tokens; logits computed only here).
    pub a: usize,
    /// Refresh cycle: diffusion steps per phase (1 refresh + n-1 normal).
    pub refresh: usize,
    /// Phase-level KV caching; false = pruning-only (Table 1 ablation).
    pub cache: bool,
}

impl Default for WdConfig {
    /// Paper defaults scaled to the sim substrate: the paper uses
    /// W_ex=128/A=16/refresh=32 on Dream (S up to 1024); at S=256 we default
    /// W_ex=64 (the LLaDA-Base setting) keeping A and refresh as published.
    fn default() -> Self {
        WdConfig { w_ex: 64, a: 16, refresh: 32, cache: true }
    }
}

pub struct WindowDiffusion {
    pub cfg: WdConfig,
}

impl Default for WindowDiffusion {
    fn default() -> Self {
        WindowDiffusion::new(WdConfig::default())
    }
}

impl WindowDiffusion {
    pub fn new(cfg: WdConfig) -> WindowDiffusion {
        assert!(cfg.a >= 1 && cfg.w_ex >= cfg.a && cfg.refresh >= 1);
        WindowDiffusion { cfg }
    }
}

impl Strategy for WindowDiffusion {
    fn name(&self) -> String {
        let c = &self.cfg;
        if c.cache {
            format!("window[w{}/a{}/r{}]", c.w_ex, c.a, c.refresh)
        } else {
            format!("window-nocache[w{}/a{}]", c.w_ex, c.a)
        }
    }

    fn generate(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<GenResult> {
        let cfg = &self.cfg;
        let sp = exec.special();
        let vocab = exec.arch().vocab;
        let c_ladder = exec.c_ladder(req.s);
        let r_ladder = exec.r_ladder(req.s);
        let mut state = SeqState::new(&req.prompt, req.gen_len, req.s, sp.mask,
                                      sp.eos, sp.pad)?;
        let schedule = DecodeSchedule::fixed(req.tokens_per_step);
        let mut counts = StepCounts::default();
        let t0 = Instant::now();
        let mut step = 0usize;
        let phase_len = if cfg.cache { cfg.refresh } else { 1 };

        'phases: while !state.done() {
            if step >= req.step_cap() {
                return Err(anyhow!("step cap {} exceeded", req.step_cap()));
            }
            // -- phase boundary: rebuild layout over current decode state --
            let layout = WindowLayout::build(&state, cfg.w_ex, &c_ladder)?;
            let mut kv = None;
            let phase_start_step = step;
            let mut phase_decoded: Vec<usize> = Vec::new();

            for step_in_phase in 0..phase_len {
                if state.done() || step >= req.step_cap() {
                    break;
                }
                let active = state.undecoded_prefix(cfg.a);
                if active.is_empty() {
                    break;
                }
                // internal window escaped the external window -> new phase
                if active.iter().any(|&p| !layout.contains(p)) {
                    continue 'phases;
                }

                let picked = if step_in_phase == 0 || !cfg.cache {
                    // refresh step (or pruning-only step): full window forward
                    let (logits, fresh_kv) = exec.window(
                        req.s,
                        layout.c,
                        &layout.ids_padded(&state),
                        &layout.pos_padded(),
                        &layout.cvalid,
                    )?;
                    counts.window += 1;
                    counts.token_slots += layout.c;
                    kv = Some(fresh_kv);
                    // NOTE: after a refresh, earlier-phase decodes are in the
                    // cache; the phase-decoded set restarts here.
                    phase_decoded.clear();
                    let cands = candidates(active.iter().map(|&p| {
                        let slot = layout.slot(p).expect("active in layout");
                        (p, &logits[slot * vocab..(slot + 1) * vocab])
                    }));
                    select_top_k(cands, schedule.at(step))
                } else {
                    // normal step: recompute actives + in-phase decoded only
                    let cs = match ComputeSet::build(&state, &layout, &active,
                                                     &phase_decoded, &r_ladder) {
                        Ok(cs) if cs.r <= layout.c
                            && buckets::pick(&r_ladder, cs.positions.len()).is_ok() =>
                        {
                            cs
                        }
                        _ => continue 'phases, // compute set outgrew buckets
                    };
                    let cache = kv.as_ref().expect("refresh precedes normal steps");
                    let (logits, new_kv) = exec.cached(
                        req.s, layout.c, cs.r, &cs.ids_r, &cs.pos_r, &cs.slot_idx,
                        &cs.rvalid, &layout.cvalid, cache,
                    )?;
                    counts.cached += 1;
                    counts.token_slots += cs.r;
                    kv = Some(new_kv);
                    let cands = candidates(
                        cs.positions[..cs.n_active]
                            .iter()
                            .map(|&p| p)
                            .enumerate()
                            .map(|(row, p)| (p, &logits[row * vocab..(row + 1) * vocab])),
                    );
                    select_top_k(cands, schedule.at(step))
                };

                if picked.is_empty() {
                    return Err(anyhow!("no candidates at step {step}"));
                }
                commit(&mut state, &picked, step, req.adaptive)?;
                for c in &picked {
                    phase_decoded.push(c.pos);
                }
                step += 1;
            }
            // safety: a phase that made zero progress would loop forever
            if step == phase_start_step {
                return Err(anyhow!("phase made no progress at step {step}"));
            }
        }
        Ok(GenResult { state, steps: step, counts, wall: t0.elapsed() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;

    fn req(gen: usize) -> GenRequest {
        GenRequest::new(vec![10, 11, 12, 13], gen, 256)
    }

    #[test]
    fn decodes_everything_with_cache() {
        let m = MockExec::new(256);
        let wd = WindowDiffusion::default();
        let r = wd.generate(&m, &req(64)).unwrap();
        assert!(r.state.done());
        assert_eq!(r.tokens_generated(), 64);
        // 2/step -> 32 steps; phases of 32 -> ~1-2 refreshes
        assert!(r.counts.window >= 1);
        assert!(r.counts.cached > r.counts.window, "{:?}", r.counts);
        assert_eq!(r.counts.full, 0);
    }

    #[test]
    fn nocache_never_calls_cached() {
        let m = MockExec::new(256);
        let wd = WindowDiffusion::new(WdConfig { cache: false, ..Default::default() });
        let r = wd.generate(&m, &req(64)).unwrap();
        assert!(r.state.done());
        assert_eq!(r.counts.cached, 0);
        assert_eq!(r.counts.window, r.steps);
    }

    #[test]
    fn same_tokens_as_full_baseline_when_prefix_local() {
        // the mock's confidence is strictly front-loaded, so window and full
        // decode identical tokens (the paper's Obs.1 regime)
        let m = MockExec::new(256);
        let wd = WindowDiffusion::default();
        let rw = wd.generate(&m, &req(48)).unwrap();
        let rf = super::super::FullBaseline.generate(&m, &req(48)).unwrap();
        assert_eq!(rw.generated(), rf.generated());
    }

    #[test]
    fn compute_cost_below_full_baseline() {
        let m = MockExec::new(256);
        let wd = WindowDiffusion::default();
        let rw = wd.generate(&m, &req(96)).unwrap();
        let m2 = MockExec::new(256);
        let rf = super::super::FullBaseline.generate(&m2, &req(96)).unwrap();
        assert!(
            rw.counts.token_slots * 2 < rf.counts.token_slots,
            "window {} vs full {}",
            rw.counts.token_slots,
            rf.counts.token_slots
        );
    }

    #[test]
    fn adaptive_eos_prunes() {
        let m = MockExec::new(256).with_eos_at(20);
        let wd = WindowDiffusion::default();
        let mut rq = req(128);
        rq.adaptive = true;
        let r = wd.generate(&m, &rq).unwrap();
        assert!(r.state.done());
        assert_eq!(r.state.eos_pos, Some(20));
        assert_eq!(r.tokens_generated(), 16); // 4..20
        assert!(r.steps < 16);
    }

    #[test]
    fn small_window_still_completes() {
        let m = MockExec::new(256);
        let wd = WindowDiffusion::new(WdConfig { w_ex: 16, a: 4, refresh: 8, cache: true });
        let mut rq = req(100);
        rq.tokens_per_step = 1;
        let r = wd.generate(&m, &rq).unwrap();
        assert!(r.state.done());
        assert_eq!(r.tokens_generated(), 100);
    }

    #[test]
    fn internal_window_escape_forces_new_phase() {
        // a == w_ex: every decode exhausts the window immediately, forcing
        // phase turnover; must still terminate correctly
        let m = MockExec::new(256);
        let wd = WindowDiffusion::new(WdConfig { w_ex: 8, a: 8, refresh: 32, cache: true });
        let r = wd.generate(&m, &req(64)).unwrap();
        assert!(r.state.done());
    }
}
