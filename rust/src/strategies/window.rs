//! **Window-Diffusion** — the paper's method (§4).
//!
//! Denoising is partitioned into phases. Each phase:
//!
//! 1. builds the window layout (all decoded tokens ∥ external window of the
//!    first `w_ex` undecoded positions; far-field pruned),
//! 2. runs one **refresh step**: a full forward over the layout
//!    (`fwd_window`), writing every slot's K/V into the phase cache,
//! 3. runs **normal steps** until the refresh cycle elapses: only the active
//!    tokens (internal window, first `a` undecoded) plus tokens decoded
//!    earlier in the phase are recomputed (`fwd_cached`); buffer tokens and
//!    pre-phase decoded tokens are served from the cache,
//! 4. decodes top-confidence actives each step; the internal window slides
//!    right as tokens decode.
//!
//! `cache: false` gives the pruning-only ablation of Table 1: the layout is
//! rebuilt and fully recomputed every step (phase length 1, no reuse).
//!
//! A phase also ends early when the internal window escapes the layout
//! (every external-window slot decoded) or the compute set outgrows the `r`
//! buckets that fit the cached window.

use anyhow::{anyhow, Result};

use super::machine::{kv_slot_bytes, Session, SessionCore, StepMachine, StepOutcome};
use super::{commit, Strategy};
use crate::coordinator::policies::{candidates, select_top_k, DecodeSchedule};
use crate::coordinator::{
    ComputeSet, GenRequest, Planned, StepExec, StepOutputs, StepPlan, WindowLayout,
};
use crate::runtime::buckets;
use crate::scheduler::kvstore::KvHandle;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WdConfig {
    /// External window length (undecoded prefix retained as context).
    pub w_ex: usize,
    /// Internal window length (active tokens; logits computed only here).
    pub a: usize,
    /// Refresh cycle: diffusion steps per phase (1 refresh + n-1 normal).
    pub refresh: usize,
    /// Phase-level KV caching; false = pruning-only (Table 1 ablation).
    pub cache: bool,
}

impl Default for WdConfig {
    /// Paper defaults scaled to the sim substrate: the paper uses
    /// W_ex=128/A=16/refresh=32 on Dream (S up to 1024); at S=256 we default
    /// W_ex=64 (the LLaDA-Base setting) keeping A and refresh as published.
    fn default() -> Self {
        WdConfig { w_ex: 64, a: 16, refresh: 32, cache: true }
    }
}

pub struct WindowDiffusion {
    pub cfg: WdConfig,
}

impl Default for WindowDiffusion {
    fn default() -> Self {
        WindowDiffusion::new(WdConfig::default())
    }
}

impl WindowDiffusion {
    pub fn new(cfg: WdConfig) -> WindowDiffusion {
        assert!(cfg.a >= 1 && cfg.w_ex >= cfg.a && cfg.refresh >= 1);
        WindowDiffusion { cfg }
    }
}

/// One phase's continuation state (dropped at every phase boundary).
struct WdPhase {
    layout: WindowLayout,
    /// Handle to the phase KV segment in the session's `KvStore` (possibly
    /// shared with other sessions via content-addressed prefix reuse).
    kv: Option<KvHandle>,
    /// Positions decoded since the phase's refresh (recomputed each normal
    /// step until the next refresh caches them).
    phase_decoded: Vec<usize>,
    step_in_phase: usize,
}

/// Context carried from `plan` to `apply` (what the outputs mean).
enum WdPending {
    /// Refresh / pruning-only step: decode among `active` via layout slots.
    Refresh { active: Vec<usize> },
    /// Normal cached step: decode among the compute set's active prefix.
    /// The phase KV moved into the plan; `apply` installs the returned one.
    Normal { cs: ComputeSet },
}

struct WindowMachine {
    cfg: WdConfig,
    vocab: usize,
    schedule: DecodeSchedule,
    c_ladder: Vec<usize>,
    r_ladder: Vec<usize>,
    kv_slot_bytes: usize,
    phase: Option<WdPhase>,
    pending: Option<WdPending>,
}

impl StepMachine for WindowMachine {
    fn plan(&mut self, core: &mut SessionCore) -> Result<Planned> {
        debug_assert!(self.pending.is_none(), "plan while a plan is outstanding");
        if core.state.done() {
            return Ok(Planned::Finished);
        }
        core.cap_guard()?;
        let phase_len = if self.cfg.cache { self.cfg.refresh } else { 1 };
        // A quantum needs at most one phase rebuild before it can plan: a
        // fresh phase always contains the internal window and its refresh
        // step always decodes. Three attempts is one of safety margin.
        for _attempt in 0..3 {
            if self.phase.is_none() {
                let layout = WindowLayout::build(&core.state, self.cfg.w_ex, &self.c_ladder)?;
                self.phase = Some(WdPhase {
                    layout,
                    kv: None,
                    phase_decoded: Vec::new(),
                    step_in_phase: 0,
                });
            }
            let ph = self.phase.as_mut().unwrap();
            // refresh cycle elapsed -> phase boundary
            if ph.step_in_phase >= phase_len {
                self.phase = None;
                continue;
            }
            let active = core.state.undecoded_prefix(self.cfg.a);
            debug_assert!(!active.is_empty(), "active empty while undecoded remain");
            // internal window escaped the external window -> new phase
            if active.iter().any(|&p| !ph.layout.contains(p)) {
                self.phase = None;
                continue;
            }

            if ph.step_in_phase == 0 || !self.cfg.cache {
                // refresh step (or pruning-only step): full window forward
                let plan = StepPlan::Window {
                    s: core.req.s,
                    c: ph.layout.c,
                    ids: ph.layout.ids_padded(&core.state),
                    pos: ph.layout.pos_padded(),
                    valid: ph.layout.cvalid.clone(),
                };
                self.pending = Some(WdPending::Refresh { active });
                return Ok(Planned::Forward(plan));
            }
            // normal step: recompute actives + in-phase decoded only
            let cs = match ComputeSet::build(&core.state, &ph.layout, &active,
                                             &ph.phase_decoded, &self.r_ladder) {
                Ok(cs) if cs.r <= ph.layout.c
                    && buckets::pick(&self.r_ladder, cs.positions.len()).is_ok() =>
                {
                    cs
                }
                _ => {
                    // compute set outgrew buckets -> new phase
                    self.phase = None;
                    continue;
                }
            };
            let kv = ph.kv.take().expect("refresh precedes normal steps");
            let plan = StepPlan::Cached {
                s: core.req.s,
                c: ph.layout.c,
                r: cs.r,
                ids_r: cs.ids_r.clone(),
                pos_r: cs.pos_r.clone(),
                slot_idx: cs.slot_idx.clone(),
                rvalid: cs.rvalid.clone(),
                cvalid: ph.layout.cvalid.clone(),
                kv,
            };
            self.pending = Some(WdPending::Normal { cs });
            return Ok(Planned::Forward(plan));
        }
        // safety: a phase that makes zero progress would loop forever
        Err(anyhow!("phase made no progress at step {}", core.step))
    }

    fn apply(&mut self, core: &mut SessionCore, out: StepOutputs) -> Result<StepOutcome> {
        let pending = self
            .pending
            .take()
            .ok_or_else(|| anyhow!("apply without an outstanding plan"))?;
        let ph = self.phase.as_mut().expect("phase present while a plan is outstanding");
        let picked = match pending {
            WdPending::Refresh { active } => {
                let StepOutputs::LogitsKv(logits, fresh_kv) = out else {
                    return Err(anyhow!("window refresh expects logits + kv"));
                };
                core.counts.window += 1;
                core.counts.token_slots += ph.layout.c;
                ph.kv = Some(core.adopt_kv(fresh_kv)?);
                // NOTE: after a refresh, earlier-phase decodes are in the
                // cache; the phase-decoded set restarts here.
                ph.phase_decoded.clear();
                let cands = candidates(active.iter().map(|&p| {
                    let slot = ph.layout.slot(p).expect("active in layout");
                    (p, &logits[slot * self.vocab..(slot + 1) * self.vocab])
                }));
                select_top_k(cands, self.schedule.at(core.step))
            }
            WdPending::Normal { cs } => {
                let StepOutputs::LogitsKv(logits, new_kv) = out else {
                    return Err(anyhow!("cached step expects logits + kv"));
                };
                core.counts.cached += 1;
                core.counts.token_slots += cs.r;
                ph.kv = Some(core.adopt_kv(new_kv)?);
                let cands = candidates(
                    cs.positions[..cs.n_active]
                        .iter()
                        .copied()
                        .enumerate()
                        .map(|(row, p)| (p, &logits[row * self.vocab..(row + 1) * self.vocab])),
                );
                select_top_k(cands, self.schedule.at(core.step))
            }
        };

        if picked.is_empty() {
            return Err(anyhow!("no candidates at step {}", core.step));
        }
        commit(&mut core.state, &picked, core.step, core.req.adaptive)?;
        for c in &picked {
            ph.phase_decoded.push(c.pos);
        }
        ph.step_in_phase += 1;
        core.step += 1;
        Ok(if core.state.done() { StepOutcome::Finished } else { StepOutcome::Running })
    }

    fn cancel(&mut self, plan: StepPlan) {
        // restore the KV handle a cached plan carried; replanning from here
        // is deterministic (state is exactly as before `plan`)
        if let StepPlan::Cached { kv, .. } = plan {
            if let Some(ph) = self.phase.as_mut() {
                ph.kv = Some(kv);
            }
        }
        self.pending = None;
    }

    fn cache_bytes(&self) -> usize {
        self.phase
            .as_ref()
            .and_then(|p| p.kv.as_ref())
            .map(|kv| kv.c() * self.kv_slot_bytes)
            .unwrap_or(0)
    }

    fn evict_cache(&mut self) {
        // dropping the phase forces a refresh over a fresh layout — exactly
        // a phase boundary, so decode semantics are preserved
        self.phase = None;
    }
}

impl Strategy for WindowDiffusion {
    fn name(&self) -> String {
        let c = &self.cfg;
        if c.cache {
            format!("window[w{}/a{}/r{}]", c.w_ex, c.a, c.refresh)
        } else {
            format!("window-nocache[w{}/a{}]", c.w_ex, c.a)
        }
    }

    fn start(&self, exec: &dyn StepExec, req: &GenRequest) -> Result<Session> {
        let core = SessionCore::new(exec, req)?;
        let machine = WindowMachine {
            cfg: self.cfg.clone(),
            vocab: exec.arch().vocab,
            schedule: DecodeSchedule::fixed(req.tokens_per_step),
            c_ladder: exec.c_ladder(req.s),
            r_ladder: exec.r_ladder(req.s),
            kv_slot_bytes: kv_slot_bytes(&exec.arch()),
            phase: None,
            pending: None,
        };
        Ok(Session::new(self.name(), core, Box::new(machine)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;

    fn req(gen: usize) -> GenRequest {
        GenRequest::new(vec![10, 11, 12, 13], gen, 256)
    }

    #[test]
    fn decodes_everything_with_cache() {
        let m = MockExec::new(256);
        let wd = WindowDiffusion::default();
        let r = wd.generate(&m, &req(64)).unwrap();
        assert!(r.state.done());
        assert_eq!(r.tokens_generated(), 64);
        // 2/step -> 32 steps; phases of 32 -> ~1-2 refreshes
        assert!(r.counts.window >= 1);
        assert!(r.counts.cached > r.counts.window, "{:?}", r.counts);
        assert_eq!(r.counts.full, 0);
    }

    #[test]
    fn nocache_never_calls_cached() {
        let m = MockExec::new(256);
        let wd = WindowDiffusion::new(WdConfig { cache: false, ..Default::default() });
        let r = wd.generate(&m, &req(64)).unwrap();
        assert!(r.state.done());
        assert_eq!(r.counts.cached, 0);
        assert_eq!(r.counts.window, r.steps);
    }

    #[test]
    fn same_tokens_as_full_baseline_when_prefix_local() {
        // the mock's confidence is strictly front-loaded, so window and full
        // decode identical tokens (the paper's Obs.1 regime)
        let m = MockExec::new(256);
        let wd = WindowDiffusion::default();
        let rw = wd.generate(&m, &req(48)).unwrap();
        let rf = super::super::FullBaseline.generate(&m, &req(48)).unwrap();
        assert_eq!(rw.generated(), rf.generated());
    }

    #[test]
    fn compute_cost_below_full_baseline() {
        let m = MockExec::new(256);
        let wd = WindowDiffusion::default();
        let rw = wd.generate(&m, &req(96)).unwrap();
        let m2 = MockExec::new(256);
        let rf = super::super::FullBaseline.generate(&m2, &req(96)).unwrap();
        assert!(
            rw.counts.token_slots * 2 < rf.counts.token_slots,
            "window {} vs full {}",
            rw.counts.token_slots,
            rf.counts.token_slots
        );
    }

    #[test]
    fn adaptive_eos_prunes() {
        let m = MockExec::new(256).with_eos_at(20);
        let wd = WindowDiffusion::default();
        let mut rq = req(128);
        rq.adaptive = true;
        let r = wd.generate(&m, &rq).unwrap();
        assert!(r.state.done());
        assert_eq!(r.state.eos_pos, Some(20));
        assert_eq!(r.tokens_generated(), 16); // 4..20
        assert!(r.steps < 16);
    }

    #[test]
    fn small_window_still_completes() {
        let m = MockExec::new(256);
        let wd = WindowDiffusion::new(WdConfig { w_ex: 16, a: 4, refresh: 8, cache: true });
        let mut rq = req(100);
        rq.tokens_per_step = 1;
        let r = wd.generate(&m, &rq).unwrap();
        assert!(r.state.done());
        assert_eq!(r.tokens_generated(), 100);
    }

    #[test]
    fn internal_window_escape_forces_new_phase() {
        // a == w_ex: every decode exhausts the window immediately, forcing
        // phase turnover; must still terminate correctly
        let m = MockExec::new(256);
        let wd = WindowDiffusion::new(WdConfig { w_ex: 8, a: 8, refresh: 32, cache: true });
        let r = wd.generate(&m, &req(64)).unwrap();
        assert!(r.state.done());
    }
}
