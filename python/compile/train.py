"""Build-time trainer for the sim DLMs (LLaDA masked-diffusion objective).

The paper's method is training-free and uses off-the-shelf 7B checkpoints we
don't have; instead `make artifacts` trains tiny stand-ins on the synthetic
corpus so that inference exhibits the *real* dynamics the paper exploits
(prefix-localized confidence, post-decode KV transients). Adam is hand-rolled
(optax is not a declared dependency of the build image).

Runs once per model; weights are persisted to ``artifacts/weights_<model>.bin``
(flat little-endian f32 + manifest offsets) for the rust runtime.
"""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import Arch, diffusion_loss, init_params
from .tokenizer import EOS, PAD, Tokenizer


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def build_batches(tok: Tokenizer, fmt: str, arch: Arch, n_docs: int,
                  seq_len: int, seed: int) -> np.ndarray:
    """Pack wrapped (prompt, completion, <eos>) pairs into fixed-length rows."""
    docs = corpus.training_documents(fmt, n_docs, seed=seed)
    rows: list[list[int]] = []
    cur: list[int] = []
    for doc in docs:
        for p, t in doc:
            ids = tok.encode(p) + tok.encode(t) + [EOS]
            if len(cur) + len(ids) > seq_len:
                if cur:
                    rows.append(cur + [PAD] * (seq_len - len(cur)))
                cur = []
                if len(ids) > seq_len:
                    ids = ids[:seq_len]
            cur.extend(ids)
    if cur:
        rows.append(cur + [PAD] * (seq_len - len(cur)))
    return np.asarray(rows, np.int32)


# ---------------------------------------------------------------------------
# hand-rolled Adam
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.float32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# training loop
# ---------------------------------------------------------------------------

def train_model(tok: Tokenizer, arch: Arch, fmt: str, *, mask_id: int,
                steps: int = 350, batch: int = 8, seq_len: int | None = None,
                lr: float = 3e-3, seed: int = 0, n_docs: int = 1500,
                log_every: int = 100, log=print) -> dict:
    """Train one sim model; returns the trained param dict."""
    seq_len = seq_len or min(arch.max_seq, 256)
    data = build_batches(tok, fmt, arch, n_docs, seq_len, seed=17 if fmt == "base" else 18)
    log(f"[train] fmt={fmt} rows={data.shape[0]} seq={seq_len} steps={steps}")

    key = jax.random.PRNGKey(seed)
    key, kinit = jax.random.split(key)
    params = init_params(kinit, arch)
    opt = adam_init(params)

    @partial(jax.jit, donate_argnums=(0, 1))
    def step_fn(params, opt, key, ids):
        attn_valid = (ids != PAD).astype(jnp.float32)
        loss_mask = attn_valid
        loss, grads = jax.value_and_grad(diffusion_loss)(
            params, arch, key, ids, attn_valid, loss_mask, mask_id)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    rng = np.random.default_rng(seed)
    t0 = time.time()
    loss_hist = []
    for it in range(steps):
        idx = rng.integers(0, data.shape[0], size=batch)
        key, kstep = jax.random.split(key)
        params, opt, loss = step_fn(params, opt, kstep, jnp.asarray(data[idx]))
        loss_hist.append(float(loss))
        if (it + 1) % log_every == 0 or it == 0:
            recent = float(np.mean(loss_hist[-log_every:]))
            log(f"[train] {fmt} step {it + 1}/{steps} loss={recent:.4f} "
                f"({time.time() - t0:.0f}s)")
    first = float(np.mean(loss_hist[:20]))
    last = float(np.mean(loss_hist[-20:]))
    log(f"[train] {fmt} done: loss {first:.3f} -> {last:.3f}")
    if not last < first:
        raise RuntimeError(f"training diverged for fmt={fmt}: {first} -> {last}")
    return params
