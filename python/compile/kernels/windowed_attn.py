"""L1 Pallas kernel: windowed online-softmax attention.

This is the paper's compute hot-spot restated for the TPU programming model
(DESIGN.md §Hardware-Adaptation): the CUDA implementation gathers window
tokens and runs dense attention per threadblock; here the same computation is
a Pallas grid over (head, query-block) whose body streams the KV window
through VMEM-sized blocks with a running (max, sum, accumulator) — i.e.
flash-attention over the *window layout* rather than the full sequence.

Shapes (all static at AOT time — the rust coordinator picks a bucket):
  q       [r, H, Dh]   compute tokens of this step (active ∪ phase-decoded)
  k, v    [c, H, Dh]   KV window (cache with fresh rows already scattered in)
  kvalid  [c] f32      1.0 for live slots, 0.0 for padding/far-field

Kernel must be lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers the body to plain HLO
(while-loops + dynamic slices) that the rust runtime executes directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

# Block sizes. Every ladder capacity c is a multiple of BC and every compute
# slot count r a multiple of BR (enforced by aot.py); BR×BC tiles keep the
# VMEM working set small and map onto MXU-friendly (8k × 128) shapes.
BR = 16
BC = 64


def _attn_kernel(q_ref, k_ref, v_ref, kvalid_ref, o_ref, *, scale: float, nc: int):
    """One (head, q-block) grid cell: stream `nc` KV blocks with online softmax."""
    q = q_ref[...][:, 0, :] * scale                     # [BR, Dh]
    br = q.shape[0]
    dh = q.shape[1]

    def body(j, carry):
        m, l, acc = carry
        kb = pl.load(k_ref, (pl.dslice(j * BC, BC), 0, slice(None)))  # [BC, Dh]
        vb = pl.load(v_ref, (pl.dslice(j * BC, BC), 0, slice(None)))  # [BC, Dh]
        mask = pl.load(kvalid_ref, (pl.dslice(j * BC, BC),))          # [BC]
        s = q @ kb.T                                                   # [BR, BC]
        s = jnp.where(mask[None, :] > 0.5, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))                     # [BR]
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])                                # [BR, BC]
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ vb
        return m_new, l_new, acc_new

    m0 = jnp.full((br,), NEG_INF, dtype=q.dtype)
    l0 = jnp.zeros((br,), dtype=q.dtype)
    acc0 = jnp.zeros((br, dh), dtype=q.dtype)
    _, l, acc = jax.lax.fori_loop(0, nc, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out[:, None, :]


@functools.partial(jax.jit, static_argnames=("scale",))
def windowed_attention(q, k, v, kvalid, scale=None):
    """Pallas windowed attention; same contract as ref.windowed_attention_ref."""
    r, h, dh = q.shape
    c = k.shape[0]
    if scale is None:
        scale = dh ** -0.5
    if r % BR != 0 or c % BC != 0:
        raise ValueError(f"r={r} must be a multiple of {BR}, c={c} of {BC}")
    nc = c // BC
    grid = (h, r // BR)
    kernel = functools.partial(_attn_kernel, scale=float(scale), nc=nc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # q: one head column, one BR-row block per grid cell.
            pl.BlockSpec((BR, 1, dh), lambda hh, qb: (qb, hh, 0)),
            # k/v: the whole window for the current head stays resident.
            pl.BlockSpec((c, 1, dh), lambda hh, qb: (0, hh, 0)),
            pl.BlockSpec((c, 1, dh), lambda hh, qb: (0, hh, 0)),
            pl.BlockSpec((c,), lambda hh, qb: (0,)),
        ],
        out_specs=pl.BlockSpec((BR, 1, dh), lambda hh, qb: (qb, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((r, h, dh), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k, v, kvalid)


def vmem_bytes(r: int, c: int, dh: int, dtype_bytes: int = 4) -> int:
    """Analytic VMEM working set per grid cell (DESIGN.md §Perf / L1 target).

    q-block + full-head KV window + mask + accumulator/out block.
    """
    qb = BR * dh * dtype_bytes
    kv = 2 * c * dh * dtype_bytes
    mask = c * dtype_bytes
    acc = 2 * BR * dh * dtype_bytes
    return qb + kv + mask + acc


def mxu_utilization_estimate(r: int, c: int, dh: int) -> float:
    """Fraction of MXU-issue slots doing useful work for the (BR, BC) tiling.

    The MXU consumes (128×128)·8 tiles; a BR×Dh·BC block fills
    (BR/128)·(Dh/128 rounded up) of a tile. This is the *structural* estimate
    used in EXPERIMENTS.md §Perf — interpret mode gives no TPU wallclock.
    """
    eff_rows = min(BR, 128) / 128.0
    eff_k = min(dh, 128) / 128.0
    eff_cols = min(BC, 128) / 128.0
    return eff_rows * eff_k * eff_cols
