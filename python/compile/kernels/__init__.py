# L1: Pallas kernel(s) for the paper's compute hot-spot.
from .ref import swiglu_ref, windowed_attention_ref  # noqa: F401
from .windowed_attn import windowed_attention  # noqa: F401
