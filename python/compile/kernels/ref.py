"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: simple, obviously-right dense
implementations that pytest/hypothesis compare the kernels against, and that
the trainer uses on its (speed-insensitive) build path.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def windowed_attention_ref(q, k, v, kvalid, scale=None):
    """Dense masked attention.

    Args:
      q:      [r, H, Dh] queries (the compute tokens of this step).
      k, v:   [c, H, Dh] key/value window (cached + freshly scattered).
      kvalid: [c] bool/float — False keys are masked out (padding, far-field).
      scale:  optional softmax scale, default 1/sqrt(Dh).

    Returns:
      [r, H, Dh] attention output.
    """
    dh = q.shape[-1]
    if scale is None:
        scale = dh ** -0.5
    # [H, r, c]
    s = jnp.einsum("rhd,chd->hrc", q, k) * scale
    s = jnp.where(kvalid[None, None, :].astype(bool), s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    return jnp.einsum("hrc,chd->rhd", p, v)


def swiglu_ref(x, w_gate, w_up, w_down):
    """SwiGLU feed-forward: (silu(x Wg) * (x Wu)) Wd — [n, d] -> [n, d]."""
    g = x @ w_gate
    u = x @ w_up
    return (g * jnp.reciprocal(1.0 + jnp.exp(-g)) * u) @ w_down
