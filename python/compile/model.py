"""L2: masked-diffusion transformer (JAX) with windowed step variants.

A small LLaDA/Dream-style model: token embedding, `n_layers` pre-norm blocks
(RMSNorm → multi-head bidirectional attention with RoPE → RMSNorm → SwiGLU),
final RMSNorm, untied unembedding. No causal mask — DLMs attend globally.

Three inference entry points (each AOT-lowered per shape bucket by aot.py):

* :func:`full_step`   — baseline: full-sequence forward, logits everywhere.
* :func:`fwd_window`  — one forward over the *window layout* (decoded prefix ∥
  external window); returns logits for every slot plus per-layer K/V, i.e. the
  paper's phase **refresh step** (and the pruning-only / block-diffusion paths).
* :func:`fwd_cached`  — the paper's **normal step**: recomputes only the `r`
  compute slots (active ∪ phase-decoded, padded), scatters their fresh
  per-layer K/V into the cached window *before* attention, attends over the
  whole window through the L1 Pallas kernel, and returns updated caches.

Positions are *absolute* sequence positions (RoPE input), so pruning far-field
tokens never perturbs positional geometry (DESIGN.md §3.1).
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp

from .kernels import windowed_attention, windowed_attention_ref


@dataclass(frozen=True)
class Arch:
    """Model architecture hyper-parameters (single source of truth: manifest)."""

    d: int = 128
    n_layers: int = 4
    n_heads: int = 4
    dh: int = 32
    ffn: int = 256
    vocab: int = 1024
    max_seq: int = 256
    rope_theta: float = 10000.0

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Arch":
        return cls(**d)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def param_shapes(arch: Arch) -> dict[str, tuple[int, ...]]:
    shapes: dict[str, tuple[int, ...]] = {
        "embed": (arch.vocab, arch.d),
        "final_norm": (arch.d,),
        "unembed": (arch.d, arch.vocab),
    }
    hd = arch.n_heads * arch.dh
    for i in range(arch.n_layers):
        shapes[f"l{i}.attn_norm"] = (arch.d,)
        shapes[f"l{i}.wq"] = (arch.d, hd)
        shapes[f"l{i}.wk"] = (arch.d, hd)
        shapes[f"l{i}.wv"] = (arch.d, hd)
        shapes[f"l{i}.wo"] = (hd, arch.d)
        shapes[f"l{i}.ffn_norm"] = (arch.d,)
        shapes[f"l{i}.w_gate"] = (arch.d, arch.ffn)
        shapes[f"l{i}.w_up"] = (arch.d, arch.ffn)
        shapes[f"l{i}.w_down"] = (arch.ffn, arch.d)
    return shapes


def init_params(key, arch: Arch) -> dict[str, jnp.ndarray]:
    params = {}
    for name, shape in param_shapes(arch).items():
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else arch.d
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) * (fan_in ** -0.5)
            )
    return params


def flatten_params(params: dict) -> tuple[list[str], list[jnp.ndarray]]:
    """Canonical flat ordering (sorted names) used by AOT inputs + weights.bin."""
    names = sorted(params)
    return names, [params[n] for n in names]


def unflatten_params(names: list[str], flat) -> dict:
    return dict(zip(names, flat))


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, g, eps: float = 1e-6):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope(x, pos, theta: float):
    """Rotary embedding with absolute positions. x: [n, H, Dh], pos: [n] i32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)   # [half]
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]          # [n, half]
    cos = jnp.cos(ang)[:, None, :]                                   # [n, 1, half]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv(params, i, xn, arch: Arch):
    n = xn.shape[0]
    shp = (n, arch.n_heads, arch.dh)
    q = (xn @ params[f"l{i}.wq"]).reshape(shp)
    k = (xn @ params[f"l{i}.wk"]).reshape(shp)
    v = (xn @ params[f"l{i}.wv"]).reshape(shp)
    return q, k, v


def _ffn(params, i, h):
    xn = rmsnorm(h, params[f"l{i}.ffn_norm"])
    g = xn @ params[f"l{i}.w_gate"]
    u = xn @ params[f"l{i}.w_up"]
    return h + (g * jax.nn.sigmoid(g) * u) @ params[f"l{i}.w_down"]


def _attend(q, k, v, kvalid, use_pallas: bool):
    if use_pallas:
        return windowed_attention(q, k, v, kvalid)
    return windowed_attention_ref(q, k, v, kvalid)


# ---------------------------------------------------------------------------
# step variants
# ---------------------------------------------------------------------------

def fwd_window(params, arch: Arch, ids, pos, valid, use_pallas: bool = True):
    """Forward over the window layout; returns (logits[c,V], K[L,c,H,Dh], V[...])."""
    h = params["embed"][ids]
    kvalid = valid.astype(jnp.float32)
    ks, vs = [], []
    for i in range(arch.n_layers):
        xn = rmsnorm(h, params[f"l{i}.attn_norm"])
        q, k, v = _qkv(params, i, xn, arch)
        q = rope(q, pos, arch.rope_theta)
        k = rope(k, pos, arch.rope_theta)
        attn = _attend(q, k, v, kvalid, use_pallas)
        h = h + attn.reshape(h.shape[0], -1) @ params[f"l{i}.wo"]
        h = _ffn(params, i, h)
        ks.append(k)
        vs.append(v)
    logits = rmsnorm(h, params["final_norm"]) @ params["unembed"]
    return logits, jnp.stack(ks), jnp.stack(vs)


def full_step(params, arch: Arch, ids, valid, use_pallas: bool = True):
    """Baseline full-sequence step: logits[S,V] only (cheapest output transfer)."""
    pos = jnp.arange(ids.shape[0], dtype=jnp.int32)
    logits, _, _ = fwd_window(params, arch, ids, pos, valid, use_pallas)
    return logits


def fwd_cached(params, arch: Arch, ids_r, pos_r, slot_idx, rvalid, cvalid,
               kcache, vcache, use_pallas: bool = True):
    """Normal step: compute `r` slots against the cached `c`-window.

    Args:
      ids_r:    [r] token ids of compute slots (active ∪ phase-decoded; padded).
      pos_r:    [r] absolute positions of those slots.
      slot_idx: [r] window-slot index of each compute token; padded entries must
                be set to `c` (out of bounds) so the scatter drops them.
      rvalid:   [r] 1.0 for live compute slots.
      cvalid:   [c] 1.0 for live window slots (keys visible to attention).
      kcache/vcache: [L, c, H, Dh] caches from the last refresh / normal step.

    Returns (logits[r,V], K'[L,c,H,Dh], V'[L,c,H,Dh]) — caches with the fresh
    per-layer K/V of the compute slots scattered in (buffer rows untouched).
    """
    del rvalid  # validity is enforced via slot_idx drop-scatter + cvalid masking
    h = params["embed"][ids_r]
    kvalid = cvalid.astype(jnp.float32)
    ks, vs = [], []
    for i in range(arch.n_layers):
        xn = rmsnorm(h, params[f"l{i}.attn_norm"])
        q, k, v = _qkv(params, i, xn, arch)
        q = rope(q, pos_r, arch.rope_theta)
        k = rope(k, pos_r, arch.rope_theta)
        # Scatter fresh K/V into the cached window BEFORE attention so active
        # tokens see each other's current-step states (paper §4.3).
        kl = kcache[i].at[slot_idx].set(k, mode="drop")
        vl = vcache[i].at[slot_idx].set(v, mode="drop")
        attn = _attend(q, kl, vl, kvalid, use_pallas)
        h = h + attn.reshape(h.shape[0], -1) @ params[f"l{i}.wo"]
        h = _ffn(params, i, h)
        ks.append(kl)
        vs.append(vl)
    logits = rmsnorm(h, params["final_norm"]) @ params["unembed"]
    return logits, jnp.stack(ks), jnp.stack(vs)


# ---------------------------------------------------------------------------
# training forward (build path only — dense ref attention, batched)
# ---------------------------------------------------------------------------

def fwd_train(params, arch: Arch, ids, valid):
    """Batched full forward for the trainer: ids [B,S] -> logits [B,S,V]."""
    def one(ids1, valid1):
        return full_step(params, arch, ids1, valid1, use_pallas=False)
    return jax.vmap(one)(ids, valid)


def diffusion_loss(params, arch: Arch, key, ids, attn_valid, loss_mask, mask_id: int):
    """LLaDA masked-diffusion objective.

    For each sample draw t ~ U(eps, 1), mask each loss-eligible token with
    probability t, and weight the masked-token cross-entropy by 1/t.
    """
    b, s = ids.shape
    kt, km = jax.random.split(key)
    t = jax.random.uniform(kt, (b, 1), minval=0.05, maxval=1.0)
    noise = jax.random.uniform(km, (b, s))
    masked = (noise < t) & (loss_mask > 0)
    x_t = jnp.where(masked, mask_id, ids)
    logits = fwd_train(params, arch, x_t, attn_valid)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tok_lp = jnp.take_along_axis(logp, ids[..., None], axis=-1)[..., 0]
    w = masked.astype(jnp.float32) / t
    return -(tok_lp * w).sum() / jnp.maximum(masked.sum(), 1)
