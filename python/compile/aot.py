"""AOT pipeline: train sim models, lower step executables to HLO text, emit
the artifact manifest the rust runtime consumes.

Interchange format is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs under ``artifacts/``::

  manifest.json                 models, arch, ladders, executables, weights index
  vocab.json                    tokenizer vocab + golden encode vectors
  tasks/<task>_<fmt>.json       held-out eval instances (rust eval harness)
  weights_<model>.bin           flat little-endian f32 parameter bank
  <model>/<exec>.hlo.txt        one HLO module per (variant, bucket)
  golden.json                   end-to-end numeric goldens for rust integration

Shape buckets: window capacities `c` are multiples of the kernel's BC=64 and
compute-slot counts `r` multiples of BR=16 (DESIGN.md §3.1). The rust
coordinator pads into the smallest bucket that fits.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import (Arch, flatten_params, full_step, fwd_cached, fwd_window,
                    param_shapes, unflatten_params)
from .tokenizer import EOS, MASK, PAD, Tokenizer
from .train import train_model

try:
    from jax._src.lib import xla_client as xc
except ImportError:  # pragma: no cover
    xc = None

VOCAB_SIZE = 512
GOLDEN_TEXTS = [
    "q : compute : ( 3 + 4 ) * 2 = ? a :",
    "user : tom has 5 apples . assistant :",
    "def f ( x ) : return x + 7",
]

# ---------------------------------------------------------------------------
# model zoo
# ---------------------------------------------------------------------------

def model_zoo() -> dict[str, dict]:
    """Name -> {arch, fmt, seq_sets}. Two Dream-sims (Base/Instruct) + LLaDA-sim.

    Sizes are calibrated to the build substrate (single CPU core): large enough
    to learn the synthetic task formats and show the paper's locality dynamics,
    small enough that `make artifacts` trains all three in a few minutes.
    """
    dream = dict(d=96, n_layers=3, n_heads=4, dh=24, ffn=192,
                 vocab=VOCAB_SIZE, max_seq=256)
    llada = dict(d=64, n_layers=2, n_heads=4, dh=16, ffn=128,
                 vocab=VOCAB_SIZE, max_seq=256)
    return {
        "dream-sim-base": {"arch": Arch(**dream), "fmt": "base", "seqs": [256]},
        "dream-sim-instruct": {"arch": Arch(**dream), "fmt": "instruct",
                               "seqs": [256, 512]},
        "llada-sim-base": {"arch": Arch(**llada), "fmt": "base", "seqs": [256]},
    }


def ladders(s: int) -> tuple[list[int], list[int]]:
    """(c_ladder, r_ladder) for a max sequence length s."""
    if s <= 256:
        cs = [64, 128, 192, 256]
    else:
        cs = [64, 128, 192, 256, 384, 512]
    rs = [16, 32, 48, 64, 128, 256]
    return [c for c in cs if c <= s], [r for r in rs if r <= s]


def parse_batch_ladder(spec: str) -> list[int]:
    """Batch-lane ladder for the batched executables. B=1 is always present
    as the unbatched forms, so entries <= 1 are dropped (listing 1 is
    harmless, not an error). Empty spec disables batched lowering."""
    if not spec:
        return []
    return sorted({b for b in (int(x) for x in spec.split(",") if x.strip()) if b > 1})


# ---------------------------------------------------------------------------
# bucket pruning (--prune-buckets)
# ---------------------------------------------------------------------------
#
# The batch ladder multiplies AOT lowering time (~4x executables), yet a
# production deployment dispatches only a handful of (B, s, c, r) combos.
# The rust scheduler counts every dispatch per bucket and exports them on
# GET /metrics as `forwards.<kind>.buckets` keyed by the batched-executable
# *suffix* (`b{B}_s{S}[_c{C}[_r{R}]]`). Feeding that dump back in via
# `--prune-buckets` skips lowering batched combos that were never hit; the
# manifest records them under "pruned" and the rust engine's batched
# dispatch (which probes `has_executable` before stacking lanes) falls back
# to its solo loop for those buckets instead of erroring. B=1 forms are
# never pruned — they ARE the fallback.

#: A bucket key / executable name ending in the batched suffix.
_BUCKET_KEY_RE = re.compile(r"(?:^|_)(b\d+_s\d+(?:_c\d+)?(?:_r\d+)?)$")


def batched_suffix(b: int, s: int, c: int | None = None,
                   r: int | None = None) -> str:
    """Bucket key of one batched executable (`b4_s256_c64_r16`, ...)."""
    key = f"b{b}_s{s}"
    if c is not None:
        key += f"_c{c}"
    if r is not None:
        key += f"_r{r}"
    return key


def parse_prune_dump(obj) -> set[str]:
    """Extract the *hit* bucket keys from a forward-count dump.

    Accepts any of: the full ``GET /metrics`` JSON, its ``forwards``
    sub-object, or a flat ``{key: count}`` map — keys may be bare bucket
    keys or full executable names (``fwd_cached_b4_s256_c64_r16``). Any
    numeric leaf with a positive count whose key ends in a batched suffix
    counts as a hit; everything else is ignored.
    """
    hits: set[str] = set()

    def note(key, count) -> None:
        if not isinstance(key, str) or not isinstance(count, (int, float)):
            return
        if isinstance(count, bool) or count <= 0:
            return
        m = _BUCKET_KEY_RE.search(key)
        if m:
            hits.add(m.group(1))

    def walk(o) -> None:
        if isinstance(o, dict):
            for k, v in o.items():
                if isinstance(v, (dict, list)):
                    walk(v)
                else:
                    note(k, v)
        elif isinstance(o, list):
            for v in o:
                walk(v)

    walk(obj)
    return hits


# ---------------------------------------------------------------------------
# HLO text lowering
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def spec_of(sds) -> dict:
    return {"dtype": "f32" if sds.dtype == jnp.float32 else "i32",
            "shape": list(sds.shape)}


def lower_exec(fn, step_specs: list[tuple[str, object]],
               weight_specs: list[tuple[str, object]], out_names: list[str],
               path: str) -> dict:
    """Lower fn(*step, *weights) to HLO text at `path`; return manifest entry."""
    args = [s for _, s in step_specs] + [s for _, s in weight_specs]
    # keep_unused: the rust runtime binds inputs positionally from the
    # manifest; jax must not DCE params the compute happens not to read
    # (e.g. rvalid, whose validity is enforced via the drop-scatter).
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    out_avals = lowered.out_info
    flat_out = jax.tree_util.tree_leaves(out_avals)
    return {
        "file": os.path.relpath(path, os.path.dirname(os.path.dirname(path))),
        "inputs": [dict(name=n, **spec_of(s)) for n, s in step_specs],
        "weights_appended": True,
        "outputs": [
            {"name": out_names[i], "dtype": "f32", "shape": list(flat_out[i].shape)}
            for i in range(len(flat_out))
        ],
    }


def build_executables(name: str, arch: Arch, params: dict, seqs: list[int],
                      out_dir: str, attn: str, b_ladder: list[int] | None = None,
                      hit_buckets: set[str] | None = None,
                      log=print) -> tuple[list[dict], list[str]]:
    """Lower the full/window/cached executable matrix for one model.

    With a non-empty ``b_ladder``, each (variant, bucket) additionally gets
    batched forms with a leading batch dim B (``full_step_b{B}_s{S}`` etc.):
    the single-sequence step fn vmapped over B lanes, with a ``lane_valid``
    [B] input multiplied into each lane's validity mask so padding lanes are
    inert in-graph — the cross-session micro-batching substrate
    (DESIGN.md §"Batched execution"). A batched variant that fails to lower
    (e.g. a kernel without a batching rule) is skipped with a warning: the
    rust engine falls back to solo loops for buckets it can't find.

    With ``hit_buckets`` (from ``--prune-buckets``), batched combos whose
    suffix is absent from the set are not lowered at all; their names are
    returned as the second element for the manifest's "pruned" record.
    Returns ``(manifest entries, pruned executable names)``.
    """
    use_pallas = attn == "pallas"
    b_ladder = b_ladder or []
    names, flat_w = flatten_params(params)
    weight_specs = [(n, f32(params[n].shape)) for n in names]
    l, h, dh = arch.n_layers, arch.n_heads, arch.dh
    os.makedirs(os.path.join(out_dir, name), exist_ok=True)
    entries = []
    pruned: list[str] = []

    def add(exec_name, fn, step_specs, out_names, optional=False):
        t0 = time.time()
        path = os.path.join(out_dir, name, f"{exec_name}.hlo.txt")
        try:
            e = lower_exec(fn, step_specs, weight_specs, out_names, path)
        except Exception as err:  # pragma: no cover - depends on jax version
            if not optional:
                raise
            log(f"  [aot] {name}/{exec_name} SKIPPED ({err})")
            return
        e["name"] = exec_name
        entries.append(e)
        log(f"  [aot] {name}/{exec_name} ({time.time() - t0:.1f}s)")

    def add_batched(exec_name, key, fn, step_specs, out_names):
        """Lower a batched (B > 1) variant unless its bucket was pruned."""
        if hit_buckets is not None and key not in hit_buckets:
            pruned.append(exec_name)
            return
        add(exec_name, fn, step_specs, out_names, optional=True)

    for s in seqs:
        c_ladder, r_ladder = ladders(s)

        def mk_full(s_):
            def fn(ids, valid, *flat):
                p = unflatten_params(names, flat)
                return (full_step(p, arch, ids, valid, use_pallas),)
            return fn

        def mk_full_b(s_):
            def fn(ids, valid, lane_valid, *flat):
                p = unflatten_params(names, flat)
                def one(ids1, valid1, lv1):
                    return full_step(p, arch, ids1, valid1 * lv1, use_pallas)
                return (jax.vmap(one)(ids, valid, lane_valid),)
            return fn

        add(f"full_step_s{s}", mk_full(s),
            [("ids", i32((s,))), ("valid", f32((s,)))], ["logits"])
        for b in b_ladder:
            add_batched(f"full_step_b{b}_s{s}", batched_suffix(b, s), mk_full_b(s),
                        [("ids", i32((b, s))), ("valid", f32((b, s))),
                         ("lane_valid", f32((b,)))],
                        ["logits"])

        for c in c_ladder:
            def mk_win(c_):
                def fn(ids, pos, valid, *flat):
                    p = unflatten_params(names, flat)
                    return fwd_window(p, arch, ids, pos, valid, use_pallas)
                return fn

            def mk_win_b(c_):
                def fn(ids, pos, valid, lane_valid, *flat):
                    p = unflatten_params(names, flat)
                    def one(ids1, pos1, valid1, lv1):
                        return fwd_window(p, arch, ids1, pos1, valid1 * lv1,
                                          use_pallas)
                    return jax.vmap(one)(ids, pos, valid, lane_valid)
                return fn

            add(f"fwd_window_s{s}_c{c}", mk_win(c),
                [("ids", i32((c,))), ("pos", i32((c,))), ("valid", f32((c,)))],
                ["logits", "kcache", "vcache"])
            for b in b_ladder:
                add_batched(f"fwd_window_b{b}_s{s}_c{c}", batched_suffix(b, s, c),
                            mk_win_b(c),
                            [("ids", i32((b, c))), ("pos", i32((b, c))),
                             ("valid", f32((b, c))), ("lane_valid", f32((b,)))],
                            ["logits", "kcache", "vcache"])

            for r in [r for r in r_ladder if r <= c]:
                def mk_cached(c_, r_):
                    def fn(ids_r, pos_r, slot_idx, rvalid, cvalid, kc, vc, *flat):
                        p = unflatten_params(names, flat)
                        return fwd_cached(p, arch, ids_r, pos_r, slot_idx,
                                          rvalid, cvalid, kc, vc, use_pallas)
                    return fn

                def mk_cached_b(c_, r_):
                    def fn(ids_r, pos_r, slot_idx, rvalid, cvalid, kc, vc,
                           lane_valid, *flat):
                        p = unflatten_params(names, flat)
                        def one(ir1, pr1, si1, rv1, cv1, k1, v1, lv1):
                            return fwd_cached(p, arch, ir1, pr1, si1,
                                              rv1 * lv1, cv1 * lv1, k1, v1,
                                              use_pallas)
                        return jax.vmap(one)(ids_r, pos_r, slot_idx, rvalid,
                                             cvalid, kc, vc, lane_valid)
                    return fn

                add(f"fwd_cached_s{s}_c{c}_r{r}", mk_cached(c, r),
                    [("ids_r", i32((r,))), ("pos_r", i32((r,))),
                     ("slot_idx", i32((r,))), ("rvalid", f32((r,))),
                     ("cvalid", f32((c,))),
                     ("kcache", f32((l, c, h, dh))),
                     ("vcache", f32((l, c, h, dh)))],
                    ["logits", "kcache", "vcache"])
                for b in b_ladder:
                    add_batched(f"fwd_cached_b{b}_s{s}_c{c}_r{r}",
                                batched_suffix(b, s, c, r), mk_cached_b(c, r),
                                [("ids_r", i32((b, r))), ("pos_r", i32((b, r))),
                                 ("slot_idx", i32((b, r))), ("rvalid", f32((b, r))),
                                 ("cvalid", f32((b, c))),
                                 ("kcache", f32((b, l, c, h, dh))),
                                 ("vcache", f32((b, l, c, h, dh))),
                                 ("lane_valid", f32((b,)))],
                                ["logits", "kcache", "vcache"])
    if pruned:
        log(f"  [aot] {name}: pruned {len(pruned)} never-dispatched batched "
            f"combos (--prune-buckets)")
    return entries, pruned


# ---------------------------------------------------------------------------
# weights + goldens
# ---------------------------------------------------------------------------

def validate_offset_table(index: list[dict], total_bytes: int) -> None:
    """Enforce the manifest offset-table grammar (ISSUE 5).

    The rust ``WeightBank`` memory-maps ``weights_<model>.bin`` and slices
    parameters straight out of the mapping using this table, so the grammar
    is a wire contract (mirrored by ``runtime/weights.rs::
    validate_offset_table``; pinned by ``tests/test_offset_table.py``):

    * ``offset`` is a **byte** offset into the flat little-endian f32
      stream, 4-byte aligned;
    * ``size`` is the element count and equals ``prod(shape)`` (scalars: 1);
    * entries appear in file order and tile the file **contiguously** —
      first at 0, no gaps, no overlap, ending at ``total_bytes``.
    """
    off = 0
    for e in index:
        elems = 1
        for d in e["shape"]:
            elems *= d
        if max(elems, 1) != e["size"]:
            raise ValueError(f"param {e['name']}: shape {e['shape']} has "
                             f"{elems} elems but size={e['size']}")
        if e["offset"] % 4:
            raise ValueError(f"param {e['name']}: byte offset {e['offset']} "
                             f"not 4-aligned")
        if e["offset"] != off:
            raise ValueError(f"param {e['name']}: offset {e['offset']} leaves "
                             f"a gap or overlap (expected {off})")
        off += e["size"] * 4
    if off != total_bytes:
        raise ValueError(f"offset table tiles {off} bytes, bank has "
                         f"{total_bytes}")


def write_weights(params: dict, path: str) -> tuple[list[dict], int]:
    """Write the flat f32 bank and return ``(offset table, total bytes)``.

    The table's byte offsets are what lets the rust side mmap the bank and
    slice parameters with no re-parse; see :func:`validate_offset_table`
    for the grammar it guarantees.
    """
    names, flat = flatten_params(params)
    index, off = [], 0
    with open(path, "wb") as f:
        for n, arr in zip(names, flat):
            a = np.asarray(arr, np.float32)
            f.write(a.tobytes())
            index.append({"name": n, "shape": list(a.shape), "offset": off,
                          "size": int(a.size)})
            off += a.size * 4
    validate_offset_table(index, off)
    return index, off


def write_golden(tok: Tokenizer, zoo: dict, trained: dict, out_dir: str) -> None:
    """Numeric goldens for the rust integration tests (dream-sim-base)."""
    name = "dream-sim-base"
    arch: Arch = zoo[name]["arch"]
    params = trained[name]
    prompt = tok.encode("q : compute : ( 3 + 4 ) * 2 = ? a :")
    s = arch.max_seq
    ids = np.full((s,), MASK, np.int32)
    ids[: len(prompt)] = prompt
    gen_len = 64
    valid = np.zeros((s,), np.float32)
    valid[: len(prompt) + gen_len] = 1.0
    logits = np.asarray(full_step(params, arch, jnp.asarray(ids),
                                  jnp.asarray(valid), use_pallas=True))
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    conf = np.asarray(jnp.max(probs, axis=-1))
    arg = np.asarray(jnp.argmax(jnp.asarray(logits), axis=-1))
    undecoded = list(range(len(prompt), len(prompt) + gen_len))
    payload = {
        "model": name,
        "prompt_ids": [int(x) for x in prompt],
        "gen_len": gen_len,
        "argmax": [int(arg[i]) for i in undecoded[:16]],
        "confidence": [round(float(conf[i]), 6) for i in undecoded[:16]],
        "logit_row0": [round(float(x), 5) for x in logits[undecoded[0]][:8]],
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(payload, f)


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="all",
                    help="comma list or 'all'")
    ap.add_argument("--attn", choices=["pallas", "ref"], default="pallas",
                    help="attention implementation lowered into the HLO")
    ap.add_argument("--batch-ladder", default="2,4,8",
                    help="comma list of batch-lane counts for the batched "
                         "executables (B=1 is always present as the unbatched "
                         "forms); empty string disables batched lowering")
    ap.add_argument("--prune-buckets", default=None, metavar="COUNTS_JSON",
                    help="production per-kind forward-count dump (the GET "
                         "/metrics JSON, its 'forwards' object, or a flat "
                         "{key: count} map): batched (B>1) combos absent "
                         "from it are not lowered; the manifest records "
                         "them under 'pruned' and the engine falls back to "
                         "solo dispatch for those buckets")
    ap.add_argument("--train-steps", type=int, default=350)
    ap.add_argument("--retrain", action="store_true",
                    help="retrain even if cached weights exist")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    zoo = model_zoo()
    wanted = list(zoo) if args.models == "all" else args.models.split(",")
    batch_ladder = parse_batch_ladder(args.batch_ladder)
    hit_buckets = None
    if args.prune_buckets:
        with open(args.prune_buckets) as f:
            hit_buckets = parse_prune_dump(json.load(f))
        print(f"[aot] prune: {len(hit_buckets)} batched bucket keys observed "
              f"in {args.prune_buckets}")
        if len(wanted) > 1:
            # the /metrics counters carry no model dimension: one server's
            # dump says nothing about models it never served, so applying it
            # across the zoo prunes their batched combos on zero evidence
            print(f"[aot] prune WARNING: one forward-count dump applied to "
                  f"{len(wanted)} models ({','.join(wanted)}); models the "
                  f"dump's server never ran will lose ALL batched combos "
                  f"(solo fallback). Pass --models <served-model> to scope "
                  f"pruning to the model the dump describes.")

    # 1. vocabulary (+ golden encode vectors for the rust tokenizer parity test)
    tok = Tokenizer().fit(corpus.all_surface_texts())
    if len(tok) > VOCAB_SIZE:
        raise RuntimeError(f"vocab {len(tok)} exceeds budget {VOCAB_SIZE}")
    tok.save(os.path.join(out_dir, "vocab.json"), golden=GOLDEN_TEXTS)
    print(f"[aot] vocab: {len(tok)} tokens (budget {VOCAB_SIZE})")

    # 2. eval task suites
    corpus.write_tasks(os.path.join(out_dir, "tasks"))

    # 3. per-model: train (or reuse), export weights, lower executables
    manifest: dict = {"vocab_file": "vocab.json", "tasks_dir": "tasks",
                      "attn": args.attn,
                      "special": {"pad": PAD, "mask": MASK, "eos": EOS},
                      "models": {}}
    trained: dict = {}
    for name in wanted:
        info = zoo[name]
        arch: Arch = info["arch"]
        wpath = os.path.join(out_dir, f"weights_{name}.bin")
        npz = os.path.join(out_dir, f"weights_{name}.npz")
        if os.path.exists(npz) and not args.retrain:
            print(f"[aot] {name}: reusing cached weights")
            loaded = np.load(npz)
            params = {k: jnp.asarray(loaded[k]) for k in loaded.files}
        else:
            params = train_model(tok, arch, info["fmt"], mask_id=MASK,
                                 steps=args.train_steps)
            np.savez(npz, **{k: np.asarray(v) for k, v in params.items()})
        assert set(params) == set(param_shapes(arch)), "weight/arch mismatch"
        trained[name] = params
        windex, wbytes = write_weights(params, wpath)
        execs, pruned = build_executables(name, arch, params, info["seqs"], out_dir,
                                          args.attn, b_ladder=batch_ladder,
                                          hit_buckets=hit_buckets)
        c_l, r_l = ladders(max(info["seqs"]))
        manifest["models"][name] = {
            "arch": arch.to_dict(),
            "format": info["fmt"],
            "seqs": info["seqs"],
            "c_ladder": c_l,
            "r_ladder": r_l,
            # lanes a single forward can carry; B=1 = the unbatched forms
            "b_ladder": [1] + batch_ladder,
            # batched combos skipped by --prune-buckets: the engine serves
            # these buckets with its solo fallback instead of erroring
            "pruned": pruned,
            "weights_file": os.path.basename(wpath),
            # total bank length: lets the rust WeightBank cross-check its
            # mmap against the manifest without summing the offset table
            "weight_bytes": wbytes,
            "weights": windex,
            "weight_order": sorted(params),
            "executables": execs,
        }

    # 4. goldens + manifest
    if "dream-sim-base" in trained:
        write_golden(tok, zoo, trained, out_dir)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest written to {out_dir}/manifest.json")


if __name__ == "__main__":
    sys.exit(main())
