"""Word-level tokenizer shared between the python build path and the rust runtime.

The tokenizer is deliberately trivial so that the rust side
(``rust/src/tokenizer``) can implement the exact same algorithm and be checked
against golden vectors emitted by :func:`write_vocab`:

* text is split on whitespace;
* every digit is its own token (``"42"`` -> ``["4", "2"]``) so the tiny model
  can learn arithmetic compositionally;
* runs of letters/underscore and single punctuation characters are tokens;
* unknown words map to ``<unk>``.

Special ids are fixed and baked into the artifact manifest:
``<pad>=0, <mask>=1, <eos>=2, <bos>=3, <unk>=4``.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

PAD, MASK, EOS, BOS, UNK = 0, 1, 2, 3, 4
SPECIALS = ["<pad>", "<mask>", "<eos>", "<bos>", "<unk>"]

_TOKEN_RE = re.compile(r"[A-Za-z_]+|[0-9]|[^\sA-Za-z0-9_]")


def pretokenize(text: str) -> list[str]:
    """Split text into surface tokens (digits are always singletons)."""
    return _TOKEN_RE.findall(text)


@dataclass
class Tokenizer:
    """Closed-vocabulary word tokenizer with fixed special ids."""

    vocab: list[str] = field(default_factory=lambda: list(SPECIALS))
    index: dict[str, int] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.index:
            self.index = {w: i for i, w in enumerate(self.vocab)}

    # -- vocabulary construction ------------------------------------------------
    def add(self, word: str) -> int:
        if word not in self.index:
            self.index[word] = len(self.vocab)
            self.vocab.append(word)
        return self.index[word]

    def fit(self, texts: list[str]) -> "Tokenizer":
        for t in texts:
            for tok in pretokenize(t):
                self.add(tok)
        return self

    # -- encode / decode ---------------------------------------------------------
    def encode(self, text: str, bos: bool = False, eos: bool = False) -> list[int]:
        ids = [self.index.get(tok, UNK) for tok in pretokenize(text)]
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids: list[int], skip_special: bool = True) -> str:
        words = []
        for i in ids:
            if skip_special and i < len(SPECIALS):
                continue
            words.append(self.vocab[i] if 0 <= i < len(self.vocab) else "<unk>")
        return " ".join(words)

    def __len__(self) -> int:
        return len(self.vocab)

    # -- persistence ---------------------------------------------------------------
    def save(self, path: str, golden: list[str] | None = None) -> None:
        """Write vocab plus golden encode vectors for the rust parity test."""
        payload: dict = {"vocab": self.vocab}
        if golden is not None:
            payload["golden"] = [
                {"text": g, "ids": self.encode(g)} for g in golden
            ]
        with open(path, "w") as f:
            json.dump(payload, f)

    @classmethod
    def load(cls, path: str) -> "Tokenizer":
        with open(path) as f:
            payload = json.load(f)
        return cls(vocab=list(payload["vocab"]))
