"""Synthetic corpus + task suites standing in for GSM8K / MATH / HumanEval / MBPP.

The paper evaluates Window-Diffusion on four real benchmarks with 7B models.
We have neither the models nor the benchmark harnesses (repro band 0), so per
the substitution rule we build the closest synthetic equivalents that exercise
the same code paths:

* ``synth-gsm``  — two-step arithmetic word problems, `#### <answer>` format;
* ``synth-math`` — bracketed expression evaluation;
* ``synth-he``   — tiny function synthesis ("HumanEval-like");
* ``synth-mbpp`` — short program tasks with a docstring-style prompt
  ("MBPP-like", the longest generations, used for adaptive-length runs).

Each suite has a *generator* (used both for the training corpus and for held-out
eval instances) and a canonical answer the rust grader checks. Train and eval
instances are drawn from disjoint seed ranges so eval is held out.

Two prompt formats mirror the paper's Base vs Instruct models:
``base``     -> "q : ... a : ..." few-shot style documents;
``instruct`` -> "user : ... assistant : ..." dialogues.
"""

from __future__ import annotations

import json
import os
import random
from dataclasses import dataclass

NAMES = ["tom", "amy", "sam", "lily", "max", "eva", "ben", "ana"]
ITEMS = ["apples", "pens", "books", "coins", "cards", "stars", "cups", "keys"]
VERBS_ADD = ["buys", "finds", "gets", "wins"]
VERBS_SUB = ["loses", "gives away", "drops", "sells"]

TASKS = ["synth-gsm", "synth-math", "synth-he", "synth-mbpp"]


@dataclass
class Instance:
    task: str
    prompt: str   # question text WITHOUT format wrapping
    target: str   # canonical completion text (what the model should emit)
    answer: str   # graded payload (digits joined by space, or canonical code)


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------

def _digits(n: int) -> str:
    """Render an integer the way the tokenizer sees it (digit per token)."""
    return " ".join(str(n))


def gen_gsm(rng: random.Random) -> Instance:
    name = rng.choice(NAMES)
    item = rng.choice(ITEMS)
    a = rng.randint(2, 9)
    b = rng.randint(1, 9)
    if rng.random() < 0.5:
        verb = rng.choice(VERBS_ADD)
        res = a + b
        op = "+"
    else:
        verb = rng.choice(VERBS_SUB)
        a = max(a, b + 1)
        res = a - b
        op = "-"
    q = (f"{name} has {_digits(a)} {item} . {name} {verb} {_digits(b)} more ."
         if op == "+" else
         f"{name} has {_digits(a)} {item} . {name} {verb} {_digits(b)} of them .")
    q += f" how many {item} does {name} have ?"
    t = f"{name} has {_digits(a)} {op} {_digits(b)} = {_digits(res)} {item} . #### {_digits(res)}"
    return Instance("synth-gsm", q, t, _digits(res))


def gen_math(rng: random.Random) -> Instance:
    a, b, c = rng.randint(1, 9), rng.randint(1, 9), rng.randint(1, 4)
    form = rng.randrange(3)
    if form == 0:
        expr, res = f"( {_digits(a)} + {_digits(b)} ) * {_digits(c)}", (a + b) * c
    elif form == 1:
        expr, res = f"{_digits(a)} * {_digits(c)} + {_digits(b)}", a * c + b
    else:
        a = max(a, b + 1)
        expr, res = f"( {_digits(a)} - {_digits(b)} ) * {_digits(c)}", (a - b) * c
    q = f"compute : {expr} = ?"
    t = f"the value is {_digits(res)} . #### {_digits(res)}"
    return Instance("synth-math", q, t, _digits(res))


HE_OPS = [
    ("add", "+"), ("sub", "-"), ("mul", "*"),
]


def gen_he(rng: random.Random) -> Instance:
    opname, op = rng.choice(HE_OPS)
    k = rng.randint(1, 9)
    q = f"write a function that returns x {op} {_digits(k)}"
    code = f"def f ( x ) : return x {op} {_digits(k)}"
    return Instance("synth-he", q, code, code)


MBPP_BODIES = [
    ("return the double of x then add K", "def f ( x ) : y = x * 2 ; return y + {k}"),
    ("return x squared minus K", "def f ( x ) : y = x * x ; return y - {k}"),
    ("return the sum of x and y times K", "def f ( x , y ) : z = x + y ; return z * {k}"),
    ("return K if x is zero else x", "def f ( x ) : return {k} if x == 0 else x"),
]


def gen_mbpp(rng: random.Random) -> Instance:
    desc, body = rng.choice(MBPP_BODIES)
    k = rng.randint(1, 9)
    q = f"task : {desc.replace('K', _digits(k))}"
    code = body.format(k=_digits(k))
    return Instance("synth-mbpp", q, code, code)


GENERATORS = {
    "synth-gsm": gen_gsm,
    "synth-math": gen_math,
    "synth-he": gen_he,
    "synth-mbpp": gen_mbpp,
}


# ---------------------------------------------------------------------------
# formatting (Base few-shot vs Instruct)
# ---------------------------------------------------------------------------

def wrap(inst: Instance, fmt: str) -> tuple[str, str]:
    """Return (prompt_text, completion_text) in the given format."""
    if fmt == "base":
        return f"q : {inst.prompt} a :", f" {inst.target}"
    return f"user : {inst.prompt} assistant :", f" {inst.target}"


def render_document(rng: random.Random, fmt: str, max_pairs: int = 4) -> list[tuple[str, str]]:
    """A training document: several wrapped (prompt, completion) pairs.

    The trainer joins pairs with the real ``<eos>`` token id (the tokenizer has
    no textual surface form for specials), so documents are returned as pair
    lists rather than flat text.
    """
    parts = []
    for _ in range(rng.randint(2, max_pairs)):
        task = rng.choice(TASKS)
        inst = GENERATORS[task](rng)
        parts.append(wrap(inst, fmt))
    return parts


# ---------------------------------------------------------------------------
# corpus + eval emission
# ---------------------------------------------------------------------------

def training_documents(fmt: str, n_docs: int, seed: int = 17) -> list[list[tuple[str, str]]]:
    rng = random.Random(seed)
    return [render_document(rng, fmt) for _ in range(n_docs)]


def eval_instances(task: str, fmt: str, n: int, seed: int = 9_000_000) -> list[dict]:
    """Held-out instances: seeds disjoint from the training range."""
    rng = random.Random(seed + hash(task) % 1000)
    out = []
    for i in range(n):
        inst = GENERATORS[task](rng)
        prompt, _ = wrap(inst, fmt)
        out.append({
            "id": f"{task}-{fmt}-{i}",
            "task": task,
            "format": fmt,
            "prompt": prompt,
            "answer": inst.answer,
            "reference": inst.target,
        })
    return out


def write_tasks(out_dir: str, n_per_task: int = 64) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for fmt in ("base", "instruct"):
        for task in TASKS:
            path = os.path.join(out_dir, f"{task}_{fmt}.json")
            with open(path, "w") as f:
                json.dump(eval_instances(task, fmt, n_per_task), f)


def all_surface_texts() -> list[str]:
    """Every text the vocabulary must cover (for Tokenizer.fit)."""
    texts = []
    for fmt, seed in (("base", 17), ("instruct", 18)):
        for doc in training_documents(fmt, 200, seed=seed):
            for p, t in doc:
                texts.append(p + t)
    for fmt in ("base", "instruct"):
        for task in TASKS:
            for inst in eval_instances(task, fmt, 64):
                texts.append(inst["prompt"] + " " + inst["reference"])
    return texts
