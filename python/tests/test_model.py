"""L2 model invariants — the semantic contracts the rust coordinator relies on.

The key one: a `fwd_cached` step whose caches come straight from a
`fwd_window` refresh must reproduce the window forward's logits exactly at the
compute slots (the KV it scatters equals what is already cached). That
equivalence is what makes phase-level caching *exact at the refresh boundary*;
every later divergence is the paper's controlled approximation.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (Arch, flatten_params, full_step, fwd_cached,
                           fwd_window, init_params, param_shapes, rmsnorm,
                           rope, unflatten_params)

ARCH = Arch(d=64, n_layers=2, n_heads=4, dh=16, ffn=128, vocab=256, max_seq=128)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), ARCH)


def _window(params, c, seed=0, invalid_tail=0):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(5, ARCH.vocab, c), jnp.int32)
    pos = jnp.arange(c, dtype=jnp.int32)
    valid = jnp.ones(c, jnp.float32)
    if invalid_tail:
        valid = valid.at[c - invalid_tail:].set(0.0)
    return ids, pos, valid


def test_window_shapes(params):
    c = 64
    ids, pos, valid = _window(params, c)
    logits, k, v = fwd_window(params, ARCH, ids, pos, valid)
    assert logits.shape == (c, ARCH.vocab)
    assert k.shape == (ARCH.n_layers, c, ARCH.n_heads, ARCH.dh)
    assert v.shape == k.shape


def test_full_step_equals_window_at_s(params):
    s = 128
    ids, pos, valid = _window(params, s)
    logits = full_step(params, ARCH, ids, valid)
    logits_w, _, _ = fwd_window(params, ARCH, ids, pos, valid)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_w),
                               atol=1e-5)


def test_cached_step_matches_window_after_refresh(params):
    """The refresh-boundary exactness contract (DESIGN.md §7)."""
    c, r = 128, 16
    ids, pos, valid = _window(params, c, invalid_tail=20)
    logits_w, kc, vc = fwd_window(params, ARCH, ids, pos, valid)
    idx = np.arange(40, 40 + r, dtype=np.int32)
    logits_r, _, _ = fwd_cached(params, ARCH, ids[idx], pos[idx],
                                jnp.asarray(idx), jnp.ones(r), valid, kc, vc)
    np.testing.assert_allclose(np.asarray(logits_r),
                               np.asarray(logits_w)[idx], atol=1e-4)


def test_cached_step_scatter_updates_only_compute_slots(params):
    c, r = 64, 16
    ids, pos, valid = _window(params, c)
    _, kc, vc = fwd_window(params, ARCH, ids, pos, valid)
    new_ids = ids.at[10].set(7)  # change one compute token
    idx = np.arange(8, 8 + r, dtype=np.int32)
    _, kc2, vc2 = fwd_cached(params, ARCH, new_ids[idx], pos[idx],
                             jnp.asarray(idx), jnp.ones(r), valid, kc, vc)
    kc, kc2 = np.asarray(kc), np.asarray(kc2)
    # outside the compute slots the cache is untouched
    outside = [i for i in range(c) if i < 8 or i >= 8 + r]
    np.testing.assert_allclose(kc2[:, outside], kc[:, outside], atol=0)
    # the changed token's K row differs
    assert np.abs(kc2[:, 10] - kc[:, 10]).max() > 1e-4


def test_cached_step_drop_padding(params):
    """Padded compute slots (slot_idx == c) must not corrupt the cache."""
    c, r = 64, 16
    ids, pos, valid = _window(params, c)
    _, kc, vc = fwd_window(params, ARCH, ids, pos, valid)
    idx = np.concatenate([np.arange(4, 12), np.full(8, c)]).astype(np.int32)
    _, kc2, _ = fwd_cached(params, ARCH, ids[:r], pos[:r], jnp.asarray(idx),
                           jnp.ones(r), valid, kc, vc)
    outside = [i for i in range(c) if not (4 <= i < 12)]
    np.testing.assert_allclose(np.asarray(kc2)[:, outside],
                               np.asarray(kc)[:, outside], atol=0)


def test_far_field_pruning_locality(params):
    """Pruning distant *masked* tokens perturbs near-frontier logits only
    mildly compared to pruning nearby ones — the Obs.-2 structure the method
    relies on (here just a sanity check that masking works at all: an
    invalid tail must change logits less than an invalid head)."""
    c = 128
    ids, pos, valid = _window(params, c)
    base, _, _ = fwd_window(params, ARCH, ids, pos, valid)
    tail_off = valid.at[96:].set(0.0)
    head_off = valid.at[:32].set(0.0)
    lt, _, _ = fwd_window(params, ARCH, ids, pos, tail_off)
    lh, _, _ = fwd_window(params, ARCH, ids, pos, head_off)
    probe = slice(33, 64)  # tokens near the front, far from the tail
    d_tail = float(np.abs(np.asarray(lt - base))[probe].mean())
    d_head = float(np.abs(np.asarray(lh - base))[probe].mean())
    assert d_tail < d_head


def test_rope_position_dependence():
    x = jnp.ones((4, 2, 16), jnp.float32)
    p1 = rope(x, jnp.asarray([0, 1, 2, 3], jnp.int32), 10000.0)
    p2 = rope(x, jnp.asarray([0, 5, 2, 3], jnp.int32), 10000.0)
    assert not np.allclose(np.asarray(p1)[1], np.asarray(p2)[1])
    np.testing.assert_allclose(np.asarray(p1)[0], np.asarray(p2)[0])


def test_rope_relative_invariance():
    """RoPE dot products depend only on relative offsets."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 16)), jnp.float32)
    def score(pq, pk):
        qq = rope(q, jnp.asarray([pq], jnp.int32), 10000.0)
        kk = rope(k, jnp.asarray([pk], jnp.int32), 10000.0)
        return float(jnp.sum(qq * kk))
    assert abs(score(3, 7) - score(13, 17)) < 1e-4


def test_rmsnorm_scale_invariance():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 16)),
                    jnp.float32)
    g = jnp.ones(16)
    np.testing.assert_allclose(np.asarray(rmsnorm(x, g)),
                               np.asarray(rmsnorm(x * 10.0, g)), atol=1e-5)


def test_param_flatten_roundtrip(params):
    names, flat = flatten_params(params)
    assert names == sorted(params)
    back = unflatten_params(names, flat)
    assert set(back) == set(params)
    for n in names:
        np.testing.assert_array_equal(np.asarray(back[n]), np.asarray(params[n]))


def test_param_shapes_cover_all():
    shapes = param_shapes(ARCH)
    p = init_params(jax.random.PRNGKey(1), ARCH)
    assert set(shapes) == set(p)
    for n, s in shapes.items():
        assert tuple(p[n].shape) == tuple(s)


@settings(max_examples=10, deadline=None)
@given(start=st.integers(0, 48), seed=st.integers(0, 1000))
def test_cached_equivalence_sweep(start, seed):
    """Refresh-boundary exactness holds for arbitrary compute-slot placement."""
    params = init_params(jax.random.PRNGKey(3), ARCH)
    c, r = 64, 16
    ids, pos, valid = _window(params, c, seed=seed)
    logits_w, kc, vc = fwd_window(params, ARCH, ids, pos, valid)
    idx = np.arange(start, start + r, dtype=np.int32)
    logits_r, _, _ = fwd_cached(params, ARCH, ids[idx], pos[idx],
                                jnp.asarray(idx), jnp.ones(r), valid, kc, vc)
    np.testing.assert_allclose(np.asarray(logits_r), np.asarray(logits_w)[idx],
                               atol=1e-4)
