"""L1 kernel correctness: Pallas windowed attention vs the pure-jnp oracle.

Hypothesis sweeps shapes (r, c, H, Dh within the bucket constraints), mask
patterns and value scales; every case must match the dense reference. This is
the CORE correctness signal for the compute hot path.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import windowed_attention, windowed_attention_ref
from compile.kernels.windowed_attn import (BC, BR, mxu_utilization_estimate,
                                           vmem_bytes)


def run_case(r, c, h, dh, seed, mask_frac=0.3, scale_vals=1.0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((r, h, dh)) * scale_vals, jnp.float32)
    k = jnp.asarray(rng.standard_normal((c, h, dh)) * scale_vals, jnp.float32)
    v = jnp.asarray(rng.standard_normal((c, h, dh)) * scale_vals, jnp.float32)
    kvalid = (rng.random(c) > mask_frac).astype(np.float32)
    if kvalid.sum() == 0:
        kvalid[0] = 1.0  # keep at least one visible key
    kvalid = jnp.asarray(kvalid)
    out = windowed_attention(q, k, v, kvalid)
    ref = windowed_attention_ref(q, k, v, kvalid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_basic_shapes():
    run_case(16, 64, 4, 24, seed=0)


def test_ladder_shapes():
    # the exact (r, c) buckets aot.py lowers
    for c in (64, 128, 192, 256):
        for r in (16, 48):
            run_case(r, c, 4, 24, seed=c * 100 + r)


def test_all_keys_valid():
    run_case(32, 128, 2, 16, seed=1, mask_frac=0.0)


def test_single_valid_key():
    rng = np.random.default_rng(2)
    r, c, h, dh = 16, 64, 2, 16
    q = jnp.asarray(rng.standard_normal((r, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((c, h, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((c, h, dh)), jnp.float32)
    kvalid = np.zeros(c, np.float32)
    kvalid[7] = 1.0
    out = windowed_attention(q, k, v, jnp.asarray(kvalid))
    # with one visible key, output == that key's value for every query/head
    expect = np.broadcast_to(np.asarray(v)[7][None], (r, h, dh))
    np.testing.assert_allclose(np.asarray(out), expect, atol=1e-5)


def test_large_logits_stable():
    # online softmax must not overflow with large score magnitudes
    run_case(16, 128, 2, 16, seed=3, scale_vals=30.0)


def test_rejects_misaligned_shapes():
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((10, 2, 16)), jnp.float32)  # r % 16 != 0
    k = jnp.asarray(rng.standard_normal((64, 2, 16)), jnp.float32)
    v = k
    with pytest.raises(ValueError):
        windowed_attention(q, k, v, jnp.ones(64))


@settings(max_examples=25, deadline=None)
@given(
    r_mult=st.integers(1, 4),
    c_mult=st.integers(1, 4),
    h=st.integers(1, 4),
    dh=st.sampled_from([8, 16, 24, 32]),
    seed=st.integers(0, 2 ** 16),
    mask_frac=st.floats(0.0, 0.9),
)
def test_hypothesis_sweep(r_mult, c_mult, h, dh, seed, mask_frac):
    run_case(BR * r_mult, BC * c_mult, h, dh, seed, mask_frac)


def test_vmem_budget():
    # DESIGN.md §Perf: the largest bucket must fit the 16 MiB VMEM budget
    assert vmem_bytes(256, 512, 32) < 16 * 1024 * 1024


def test_mxu_estimate_bounds():
    u = mxu_utilization_estimate(64, 256, 32)
    assert 0.0 < u <= 1.0
