"""Tokenizer unit tests + the contract the rust implementation mirrors."""

import json

import pytest
from hypothesis import given, strategies as st

from compile.tokenizer import (BOS, EOS, MASK, PAD, SPECIALS, UNK, Tokenizer,
                               pretokenize)


def test_special_ids_fixed():
    assert (PAD, MASK, EOS, BOS, UNK) == (0, 1, 2, 3, 4)
    t = Tokenizer()
    assert t.vocab[:5] == SPECIALS


def test_pretokenize_digits_split():
    assert pretokenize("42 apples") == ["4", "2", "apples"]


def test_pretokenize_punct():
    assert pretokenize("f ( x ) : x+1") == ["f", "(", "x", ")", ":", "x", "+", "1"]


def test_encode_decode_roundtrip():
    t = Tokenizer().fit(["tom has 3 apples ."])
    ids = t.encode("tom has 3 apples .")
    assert t.decode(ids) == "tom has 3 apples ."


def test_unknown_maps_to_unk():
    t = Tokenizer().fit(["hello"])
    assert t.encode("goodbye") == [UNK]


def test_bos_eos_flags():
    t = Tokenizer().fit(["x"])
    assert t.encode("x", bos=True, eos=True)[0] == BOS
    assert t.encode("x", bos=True, eos=True)[-1] == EOS


def test_fit_idempotent():
    t = Tokenizer().fit(["a b c"]).fit(["a b c"])
    assert len(t) == len(SPECIALS) + 3


def test_save_load_golden(tmp_path):
    t = Tokenizer().fit(["tom has 3 apples"])
    p = tmp_path / "vocab.json"
    t.save(str(p), golden=["tom has 3"])
    payload = json.loads(p.read_text())
    assert payload["golden"][0]["ids"] == t.encode("tom has 3")
    t2 = Tokenizer.load(str(p))
    assert t2.vocab == t.vocab
    assert t2.encode("tom has 3 apples") == t.encode("tom has 3 apples")


@given(st.text(alphabet=st.characters(min_codepoint=32, max_codepoint=126),
               max_size=80))
def test_pretokenize_total(text):
    """pretokenize never throws and never emits whitespace or multi-digit runs."""
    for tok in pretokenize(text):
        assert tok.strip() == tok and tok
        if tok[0].isdigit():
            assert len(tok) == 1


@given(st.lists(st.sampled_from(["tom", "has", "3", "7", ".", "apples"]),
                min_size=1, max_size=20))
def test_encode_decode_identity_on_vocab(words):
    t = Tokenizer().fit(["tom has 3 7 . apples"])
    text = " ".join(words)
    assert t.decode(t.encode(text)) == text
