"""Batched (vmapped) step fns must be lane-wise bit-identical to the solo
step fns, and padding lanes (lane_valid = 0) must stay finite/inert.

This is the python-side half of the batching determinism story: the rust
property tests (`rust/tests/batch_props.rs`) prove the scheduler's
coalesced stepping matches solo stepping on the mock; this file proves the
lowered batched kernels compute the same numbers per lane as the solo
kernels they vmap. Runs on the ref attention path (the pallas kernel is
exercised by test_kernel.py); jax CPU is deterministic, so equality is
exact, not approximate.
"""

import jax
import jax.numpy as jnp
import pytest

from compile.model import Arch, fwd_cached, fwd_window, full_step, init_params

S, C, R, B = 64, 64, 16, 2


@pytest.fixture(scope="module")
def setup():
    arch = Arch(d=16, n_layers=1, n_heads=2, dh=8, ffn=32, vocab=32, max_seq=S)
    params = init_params(jax.random.PRNGKey(0), arch)
    ids = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 32)
    lane_valid = jnp.array([1.0, 0.0])  # lane 1 is a padding lane
    return arch, params, ids, lane_valid


def assert_bitwise(a, b, what):
    assert jnp.array_equal(a, b), f"{what}: batched lane differs from solo"


def test_full_lane_matches_solo(setup):
    arch, params, ids, lane_valid = setup
    valid = jnp.ones((B, S), jnp.float32)

    def one(i, v, lv):
        return full_step(params, arch, i, v * lv, use_pallas=False)

    batched = jax.vmap(one)(ids, valid, lane_valid)
    solo = full_step(params, arch, ids[0], valid[0], use_pallas=False)
    assert_bitwise(batched[0], solo, "full logits")
    assert bool(jnp.isfinite(batched[1]).all()), "padding lane produced non-finite"


def test_window_lane_matches_solo(setup):
    arch, params, ids, lane_valid = setup
    pos = jnp.tile(jnp.arange(C, dtype=jnp.int32)[None, :], (B, 1))
    wids = ids[:, :C]
    valid = jnp.ones((B, C), jnp.float32)

    def one(i, p, v, lv):
        return fwd_window(params, arch, i, p, v * lv, use_pallas=False)

    bl, bk, bv = jax.vmap(one)(wids, pos, valid, lane_valid)
    sl, sk, sv = fwd_window(params, arch, wids[0], pos[0], valid[0],
                            use_pallas=False)
    assert_bitwise(bl[0], sl, "window logits")
    assert_bitwise(bk[0], sk, "window kcache")
    assert_bitwise(bv[0], sv, "window vcache")
    assert bool(jnp.isfinite(bl[1]).all())


def test_cached_lane_matches_solo(setup):
    arch, params, ids, lane_valid = setup
    pos = jnp.tile(jnp.arange(C, dtype=jnp.int32)[None, :], (B, 1))
    wids = ids[:, :C]
    wvalid = jnp.ones((B, C), jnp.float32)
    _, sk, sv = fwd_window(params, arch, wids[0], pos[0], wvalid[0],
                           use_pallas=False)
    kc = jnp.tile(sk[None], (B, 1, 1, 1, 1))
    vc = jnp.tile(sv[None], (B, 1, 1, 1, 1))
    ids_r, pos_r, slot_idx = wids[:, :R], pos[:, :R], pos[:, :R]
    rvalid = jnp.ones((B, R), jnp.float32)
    cvalid = jnp.ones((B, C), jnp.float32)

    def one(ir, pr, si, rv, cv, k, v, lv):
        return fwd_cached(params, arch, ir, pr, si, rv * lv, cv * lv, k, v,
                          use_pallas=False)

    cl, ck, cv_out = jax.vmap(one)(ids_r, pos_r, slot_idx, rvalid, cvalid,
                                   kc, vc, lane_valid)
    sl2, sk2, sv2 = fwd_cached(params, arch, ids_r[0], pos_r[0], slot_idx[0],
                               rvalid[0], cvalid[0], kc[0], vc[0],
                               use_pallas=False)
    assert_bitwise(cl[0], sl2, "cached logits")
    assert_bitwise(ck[0], sk2, "cached kcache")
    assert_bitwise(cv_out[0], sv2, "cached vcache")
    assert bool(jnp.isfinite(cl[1]).all())
