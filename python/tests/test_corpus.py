"""Corpus/task-suite tests: determinism, grading contracts, train/eval split."""

import random

import pytest

from compile import corpus
from compile.tokenizer import Tokenizer


@pytest.mark.parametrize("task", corpus.TASKS)
def test_generators_deterministic(task):
    a = corpus.GENERATORS[task](random.Random(5))
    b = corpus.GENERATORS[task](random.Random(5))
    assert (a.prompt, a.target, a.answer) == (b.prompt, b.target, b.answer)


def test_gsm_answer_is_digits():
    inst = corpus.gen_gsm(random.Random(1))
    assert all(ch.isdigit() for ch in inst.answer.split())
    assert f"#### {inst.answer}" in inst.target


def test_math_answer_consistent():
    rng = random.Random(2)
    for _ in range(50):
        inst = corpus.gen_math(rng)
        assert inst.target.endswith(f"#### {inst.answer}")


def test_code_tasks_answer_is_target():
    for gen in (corpus.gen_he, corpus.gen_mbpp):
        inst = gen(random.Random(3))
        assert inst.answer == inst.target
        assert inst.target.startswith("def f (")


def test_wrap_formats():
    inst = corpus.gen_gsm(random.Random(4))
    pb, _ = corpus.wrap(inst, "base")
    pi, _ = corpus.wrap(inst, "instruct")
    assert pb.startswith("q :") and pb.endswith("a :")
    assert pi.startswith("user :") and pi.endswith("assistant :")


def test_eval_instances_held_out_and_stable():
    a = corpus.eval_instances("synth-gsm", "base", 8)
    b = corpus.eval_instances("synth-gsm", "base", 8)
    assert a == b
    # train docs use seeds 17/18, eval 9M+ — no overlap of instance text
    train_prompts = set()
    for doc in corpus.training_documents("base", 50):
        train_prompts.update(p for p, _ in doc)
    eval_prompts = {x["prompt"] for x in a}
    # (identical templates can collide by chance; require mostly-disjoint)
    assert len(eval_prompts - train_prompts) >= len(eval_prompts) // 2


def test_write_tasks(tmp_path):
    corpus.write_tasks(str(tmp_path), n_per_task=4)
    files = sorted(p.name for p in tmp_path.iterdir())
    assert len(files) == 2 * len(corpus.TASKS)
    assert "synth-gsm_base.json" in files


def test_vocab_covers_eval():
    tok = Tokenizer().fit(corpus.all_surface_texts())
    for task in corpus.TASKS:
        for inst in corpus.eval_instances(task, "instruct", 16):
            ids = tok.encode(inst["prompt"] + " " + inst["reference"])
            assert 4 not in ids  # no <unk>
