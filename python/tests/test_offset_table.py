"""Manifest offset-table grammar: `write_weights`' index is the table the
rust ``WeightBank`` uses to slice parameters straight out of a memory-mapped
``weights_<model>.bin`` with no re-parse.

These tests pin the grammar against the rust parser
(``runtime/weights.rs::validate_offset_table``): byte offsets, 4-byte
alignment, contiguous ascending tiling, ``size == prod(shape)``, and
``weight_order`` (sorted names) being a permutation of the table's names.
Drift on either side is a load-time error there and a red test here.
"""

import numpy as np
import pytest

from compile.aot import validate_offset_table, write_weights


def _params():
    return {
        "b_second": np.arange(12, dtype=np.float32).reshape(3, 4),
        "a_first": np.linspace(-1.0, 1.0, 5).astype(np.float32),
        "c_scalar": np.array(2.5, dtype=np.float32),
    }


def test_write_weights_emits_contiguous_byte_offsets(tmp_path):
    path = str(tmp_path / "w.bin")
    index, total = write_weights(_params(), path)
    # file order is flatten_params order == sorted names
    assert [e["name"] for e in index] == ["a_first", "b_second", "c_scalar"]
    assert index[0]["offset"] == 0
    # offsets are BYTES: each entry starts where the previous ended
    assert index[1]["offset"] == index[0]["size"] * 4
    assert index[2]["offset"] == index[1]["offset"] + index[1]["size"] * 4
    assert total == sum(e["size"] for e in index) * 4
    # and the file is exactly the table's span
    assert (tmp_path / "w.bin").stat().st_size == total
    # scalars record size 1 (shape [])
    assert index[2]["shape"] == []
    assert index[2]["size"] == 1


def test_index_slices_the_bank_without_reparse(tmp_path):
    # the mmap contract: reading [offset, offset + size*4) out of the raw
    # file and casting to little-endian f32 reproduces each array exactly
    params = _params()
    path = str(tmp_path / "w.bin")
    index, _ = write_weights(params, path)
    blob = (tmp_path / "w.bin").read_bytes()
    for e in index:
        lo = e["offset"]
        hi = lo + e["size"] * 4
        got = np.frombuffer(blob[lo:hi], dtype="<f4").reshape(e["shape"])
        np.testing.assert_array_equal(
            got, np.asarray(params[e["name"]], np.float32)
        )


def test_weight_order_is_a_permutation_of_the_table(tmp_path):
    # the manifest's weight_order (sorted names) must resolve 1:1 into the
    # table — the rust loader rejects anything else
    params = _params()
    index, _ = write_weights(params, str(tmp_path / "w.bin"))
    assert sorted(e["name"] for e in index) == sorted(params)


def test_validate_rejects_gap():
    index = [
        {"name": "a", "shape": [2], "offset": 0, "size": 2},
        {"name": "b", "shape": [2], "offset": 16, "size": 2},  # gap: expected 8
    ]
    with pytest.raises(ValueError, match="gap or overlap"):
        validate_offset_table(index, 24)


def test_validate_rejects_overlap():
    index = [
        {"name": "a", "shape": [4], "offset": 0, "size": 4},
        {"name": "b", "shape": [4], "offset": 8, "size": 4},  # overlaps a
    ]
    with pytest.raises(ValueError, match="gap or overlap"):
        validate_offset_table(index, 24)


def test_validate_rejects_misalignment():
    index = [{"name": "a", "shape": [4], "offset": 2, "size": 4}]
    with pytest.raises(ValueError, match="not 4-aligned"):
        validate_offset_table(index, 18)


def test_validate_rejects_shape_size_mismatch():
    index = [{"name": "a", "shape": [2, 3], "offset": 0, "size": 4}]
    with pytest.raises(ValueError, match="elems but size"):
        validate_offset_table(index, 16)


def test_validate_rejects_total_mismatch():
    index = [{"name": "a", "shape": [4], "offset": 0, "size": 4}]
    with pytest.raises(ValueError, match="tiles 16 bytes"):
        validate_offset_table(index, 20)


def test_validate_accepts_the_emitted_grammar(tmp_path):
    index, total = write_weights(_params(), str(tmp_path / "w.bin"))
    # write_weights already validates; re-validating the emitted table is
    # the round-trip the rust loader performs at every engine boot
    validate_offset_table(index, total)
