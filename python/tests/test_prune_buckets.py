"""`aot.py --prune-buckets` helpers: dump parsing + bucket-key matching.

The dump comes from the rust scheduler's per-bucket forward counters on
``GET /metrics`` (``forwards.<kind>.buckets``); these tests pin the accepted
shapes and the suffix grammar so the rust `bucket_key` (scheduler/mod.rs)
and the python side can never drift apart silently.
"""

from compile.aot import batched_suffix, parse_prune_dump


def test_batched_suffix_grammar():
    assert batched_suffix(4, 256) == "b4_s256"
    assert batched_suffix(4, 256, 128) == "b4_s256_c128"
    assert batched_suffix(8, 512, 256, 48) == "b8_s512_c256_r48"


def test_parse_flat_bucket_keys():
    hits = parse_prune_dump({"b4_s256_c64_r16": 12, "b2_s256": 1})
    assert hits == {"b4_s256_c64_r16", "b2_s256"}


def test_parse_full_executable_names():
    hits = parse_prune_dump({
        "fwd_cached_b4_s256_c64_r16": 3,
        "full_step_b2_s256": 7,
        "fwd_window_b8_s256_c128": 2,
    })
    assert hits == {"b4_s256_c64_r16", "b2_s256", "b8_s256_c128"}


def test_parse_metrics_shape():
    # the nested GET /metrics layout: forwards.<kind>.buckets
    metrics = {
        "requests_total": 40,
        "forwards": {
            "cached": {
                "forwards": 30,
                "buckets": {"b1_s256_c64_r16": 20, "b4_s256_c64_r16": 10},
            },
            "window": {"forwards": 6, "buckets": {"b4_s256_c128": 6}},
            "full": {"forwards": 4, "buckets": {}},
        },
    }
    hits = parse_prune_dump(metrics)
    # b1 keys are harmless to collect but only B>1 combos are ever lowered
    assert "b4_s256_c64_r16" in hits
    assert "b4_s256_c128" in hits
    # plain counters ("forwards": 30) must not poison the hit set
    assert all(h.startswith("b") for h in hits)


def test_zero_counts_and_junk_ignored():
    hits = parse_prune_dump({
        "b4_s256_c64_r16": 0,          # never dispatched -> not a hit
        "b2_s256": -3,                 # nonsense count
        "steps_per_second": 41.5,      # gauge, not a bucket key
        "batched": True,               # bool leaf
        "note": "b4_s256",             # non-numeric leaf
    })
    assert hits == set()


def test_prune_decision_round_trip():
    # the decision aot.py makes per batched combo: lower iff key in hits
    hits = parse_prune_dump({"fwd_cached_b4_s256_c64_r16": 5})
    assert batched_suffix(4, 256, 64, 16) in hits
    assert batched_suffix(8, 256, 64, 16) not in hits
    assert batched_suffix(4, 256, 128, 16) not in hits
