//! Quickstart: load a sim DLM from the AOT artifacts and generate with
//! Window-Diffusion vs the full-sequence baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use window_diffusion::coordinator::GenRequest;
use window_diffusion::runtime::{Engine, Manifest};
use window_diffusion::strategies::{FullBaseline, Strategy, WindowDiffusion};
use window_diffusion::tokenizer::Tokenizer;

fn main() -> anyhow::Result<()> {
    // 1. load artifacts (manifest + weights + HLO executables)
    let manifest = Manifest::load(&Manifest::default_root())?;
    let engine = Engine::load(&manifest, "dream-sim-base")?;
    let tok = Tokenizer::load(&manifest.vocab_file)?;

    // 2. build a request
    let prompt = "q : compute : ( 3 + 4 ) * 2 = ? a :";
    let mut req = GenRequest::new(tok.encode(prompt), 64, 256);
    req.tokens_per_step = 1;
    req.adaptive = true; // stop at <eos>

    // 3. generate with the paper's method and the baseline
    for strat in [&WindowDiffusion::default() as &dyn Strategy, &FullBaseline] {
        let _ = strat.generate(&engine, &req)?; // warmup: compile the buckets
        let r = strat.generate(&engine, &req)?;
        println!(
            "[{}] {:?}\n  -> {} tokens, {} steps ({} refresh / {} cached / {} full), \
             {:.2}s = {:.1} tok/s\n",
            strat.name(),
            tok.decode(&r.generated()),
            r.tokens_generated(),
            r.steps,
            r.counts.window,
            r.counts.cached,
            r.counts.full,
            r.wall.as_secs_f64(),
            r.tokens_per_sec(),
        );
    }
    Ok(())
}
