//! Token-level locality probe: reproduce the paper's three §3 observations
//! on a trained sim model in one run (the analyses that *motivate*
//! Window-Diffusion).
//!
//! ```bash
//! make artifacts && cargo run --release --example locality_probe
//! ```

use window_diffusion::analysis::{confidence, stability, truncation};
use window_diffusion::runtime::{Engine, Manifest};
use window_diffusion::tokenizer::Tokenizer;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_root())?;
    let engine = Engine::load(&manifest, "dream-sim-base")?;
    let tok = Tokenizer::load(&manifest.vocab_file)?;
    let prompt = tok.encode("q : tom has 7 coins . tom loses 3 of them . how many coins does tom have ? a :");

    println!("== Obs.1: prefix-local confidence (Fig. 2) ==");
    let snaps = confidence::run_probe(&engine, &prompt, 96, 256, &[6, 12, 24], 2)?;
    for sn in &snaps {
        println!(
            "  step {:>2}: prefix-mass(25%) = {:.3}  (uniform would be 0.250)",
            sn.step,
            confidence::prefix_mass(sn, 0.25)
        );
    }

    println!("\n== Obs.2: saturating context dependence (Fig. 3) ==");
    let pts = truncation::run_probe(&engine, &prompt, 96, 256, 12, 16, &[16, 32, 64, 96], 2)?;
    for p in &pts {
        println!("  W={:>3}: KL(no-cache)={:.5}  KL(cache)={:.5}", p.w, p.kl_nocache, p.kl_cache);
    }

    println!("\n== Obs.3: post-decode V transient vs stationarity (Fig. 4) ==");
    let c = stability::run_probe(&engine, &prompt, 64, 256, 40, 12, 8, 10, 2)?;
    print!("  recently decoded  (Δ, cos):");
    for (d, v) in c.recent.iter().take(6) {
        print!(" ({d}, {v:.3})");
    }
    print!("\n  earlier decoded   (Δ, cos):");
    for (d, v) in c.early.iter().take(6) {
        print!(" ({d}, {v:.3})");
    }
    println!();
    Ok(())
}
