//! Adaptive-length code generation (the paper's §4.2 "Adaptive termination",
//! Table 3): on code tasks the useful output is much shorter than the
//! generation budget; stopping at `<eos>` while far-field pruning keeps the
//! dead tail out of every forward pass yields the paper's largest speedups
//! (up to 99× at budget 1024).
//!
//! ```bash
//! make artifacts && cargo run --release --example adaptive_codegen
//! ```

use window_diffusion::coordinator::GenRequest;
use window_diffusion::eval::{self, grade};
use window_diffusion::runtime::{Engine, Manifest};
use window_diffusion::strategies::{Strategy, WindowDiffusion};
use window_diffusion::tokenizer::Tokenizer;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_root())?;
    let engine = Engine::load(&manifest, "dream-sim-instruct")?;
    let tok = Tokenizer::load(&manifest.vocab_file)?;
    let instances = eval::load_task(&manifest.tasks_dir, "synth-mbpp", "instruct")?;
    let wd = WindowDiffusion::default();

    println!("budget  variant    latency   tokens  graded  output");
    println!("{}", "-".repeat(100));
    for budget in [64usize, 128, 224] {
        for adaptive in [false, true] {
            let inst = &instances[0];
            let mut req = GenRequest::new(tok.encode(&inst.prompt), budget, 256);
            req.adaptive = adaptive;
            req.tokens_per_step = 1;
            let r = wd.generate(&engine, &req)?;
            let text = tok.decode(&r.generated());
            let ok = grade(&inst.task, &text, &inst.answer);
            println!(
                "{:>6}  {:<9} {:>7.2}s  {:>6}  {:>6}  {}",
                budget,
                if adaptive { "adaptive" } else { "static" },
                r.wall.as_secs_f64(),
                r.tokens_generated(),
                ok,
                &text[..text.len().min(60)]
            );
        }
    }
    println!("\n(adaptive latency should stay ~flat as the budget grows; static grows linearly+)");
    Ok(())
}
