//! End-to-end serving driver + scheduler A/B comparison.
//!
//! Boots the serving stack twice over one shared engine and fires the same
//! mixed-length concurrent workload at both:
//!
//! 1. **worker-per-request** (`direct: true`) — the legacy path: each HTTP
//!    worker drives one generation to completion; concurrency exists only
//!    through blind engine-mutex interleaving;
//! 2. **scheduler** — requests become sessions; a single driver advances
//!    every in-flight session one diffusion step per quantum (round-robin),
//!    so short requests are not stuck behind long ones.
//!
//! Prints aggregate tokens/sec and latency percentiles for both (overall and
//! short-requests-only), then an **engine-replica A/B**: the same scheduler
//! workload on a 1-replica pool vs an N-replica pool (`WD_REPLICAS`, default
//! 4) with one driver worker per replica — steps/sec should scale with the
//! replica count. Then a **micro-batch A/B**: the scheduler workload at
//! coalescing widths B ∈ {1, 4, 8}, reporting steps/sec and
//! `batch_occupancy` (mean lanes per forward; the mid-flight `/sessions`
//! probe also tables per-session `age_secs` vs `busy_ms`). Then a
//! **load-adaptive coalescing A/B**: a heterogeneous workload whose window
//! geometries land on *different* `(s, c, r)` buckets, served at fixed
//! B=1, fixed B=8 (exact-bucket coalescing only) and
//! `--batch-policy adaptive` with cross-bucket promotion — steps/sec,
//! occupancy and `promoted_lanes` side by side. Then demonstrates
//! KV-pool admission control: a server with a tiny `kv_budget_bytes`
//! answers `429` instead of overcommitting, and a **well-behaved client**
//! honors the refusal's `retry_after_ms` hint (jittered backoff, no rand
//! crate) until a long-running session frees the budget. Finally a **chaos
//! drill** (ISSUE 9): the mixed workload through a chaos-wrapped 2-replica
//! pool with ~10% transient forward faults — every request must still
//! answer 200, with the injected-fault and retry counters printed side by
//! side.
//!
//! Runs against the trained sim model when artifacts exist, otherwise falls
//! back to the deterministic mock model so the comparison runs anywhere (the
//! mock replica phase adds an artificial 1 ms step cost so speedups are
//! measurable).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_batch
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use window_diffusion::coordinator::{MockExec, StepExec};
use window_diffusion::eval;
use window_diffusion::metrics::Metrics;
use window_diffusion::runtime::{ChaosConfig, ChaosPlan, Engine, EngineCell, EnginePool, Manifest};
use window_diffusion::scheduler::{BatchPolicy, KvPool, Policy, Scheduler, SchedulerConfig};
use window_diffusion::server::api::AppState;
use window_diffusion::server::http::{http_get, http_post};
use window_diffusion::server::{serve, ServerConfig};
use window_diffusion::tokenizer::Tokenizer;
use window_diffusion::trace::TraceMode;
use window_diffusion::util::json::{parse, Json};
use window_diffusion::util::stats::Summary;
use window_diffusion::util::threadpool::parallel_map;

const SHORT_GEN: usize = 24;
const LONG_GEN: usize = 96;

struct PhaseStats {
    label: String,
    wall: f64,
    tokens: usize,
    ok: usize,
    total: usize,
    /// Scheduler steps booked during the phase (0 on the direct path).
    steps: u64,
    all: Vec<f64>,
    short: Vec<f64>,
}

impl PhaseStats {
    fn steps_per_sec(&self) -> f64 {
        self.steps as f64 / self.wall.max(1e-9)
    }
}

fn toy_tokenizer() -> Tokenizer {
    let mut vocab: Vec<String> = ["<pad>", "<mask>", "<eos>", "<bos>", "<unk>"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    for i in 0..11 {
        vocab.push(format!("w{i}"));
    }
    Tokenizer::from_vocab(vocab)
}

fn build_state(
    exec: Arc<dyn StepExec + Send + Sync>,
    pool: Option<Arc<EnginePool>>,
    tok: Tokenizer,
    model_name: &str,
    sched_cfg: SchedulerConfig,
    sched_workers: usize,
    direct: bool,
) -> Arc<AppState> {
    let metrics = Arc::new(Metrics::default());
    let scheduler = Scheduler::new(Arc::clone(&exec), sched_cfg, Arc::clone(&metrics));
    scheduler.spawn_workers(sched_workers);
    Arc::new(AppState {
        exec,
        pool,
        remote: None,
        scheduler,
        tokenizer: tok,
        metrics,
        model_name: model_name.into(),
        default_strategy: "window".into(),
        default_gen_len: 64,
        s: 256,
        direct,
    })
}

/// Mid-flight `/sessions` table: queue time (age minus busy) vs engine time
/// per live session; with `--trace ring` the recorder-sourced `queue_ms`
/// and `ttft_ms` columns fill in (printed as `-` when the trace is off or
/// the first token has not committed yet).
fn print_sessions_table(label: &str, body: &str) {
    let Ok(j) = parse(body) else { return };
    let Some(rows) = j.get("sessions").as_arr() else { return };
    println!("[{label}] mid-flight /sessions: {} live", rows.len());
    if rows.is_empty() {
        return;
    }
    println!(
        "  {:>4} {:<22} {:>5} {:>9} {:>9} {:>9} {:>9}",
        "id", "strategy", "steps", "age_s", "busy_ms", "queue_ms", "ttft_ms"
    );
    for r in rows {
        let opt_ms =
            |k: &str| r.get(k).as_f64().map_or("-".to_string(), |v| format!("{v:.2}"));
        println!(
            "  {:>4} {:<22} {:>5} {:>9.3} {:>9.2} {:>9} {:>9}",
            r.get("id").as_usize().unwrap_or(0),
            r.get("strategy").as_str().unwrap_or("?"),
            r.get("steps").as_usize().unwrap_or(0),
            r.get("age_secs").as_f64().unwrap_or(0.0),
            r.get("busy_ms").as_f64().unwrap_or(0.0),
            opt_ms("queue_ms"),
            opt_ms("ttft_ms"),
        );
    }
}

fn run_phase(
    label: &str,
    state: Arc<AppState>,
    bodies: &[(String, usize)],
    concurrency: usize,
) -> anyhow::Result<PhaseStats> {
    let server = serve(
        Arc::clone(&state),
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: concurrency.max(2),
            queue_capacity: 64,
        },
    )?;
    let addr = server.addr.clone();

    // warmup (compile all buckets once so neither phase pays it in-band)
    let _ = http_post(&addr, "/generate", &bodies[0].0);

    // mid-flight introspection probe (scheduler phase shows live sessions)
    let probe_addr = addr.clone();
    let probe = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(150));
        http_get(&probe_addr, "/sessions").ok()
    });

    let steps0 = state
        .metrics
        .sched_steps_total
        .load(std::sync::atomic::Ordering::Relaxed);
    let t0 = Instant::now();
    let addr2 = addr.clone();
    let work: Vec<(String, usize)> = bodies.to_vec();
    let results = parallel_map(work, concurrency, move |(body, gen_len)| {
        let t = Instant::now();
        let r = http_post(&addr2, "/generate", &body);
        (t.elapsed().as_secs_f64(), gen_len, r)
    });
    let wall = t0.elapsed().as_secs_f64();

    if let Ok(Some((200, body))) = probe.join() {
        print_sessions_table(label, &body);
    }

    let mut stats = PhaseStats {
        label: label.to_string(),
        wall,
        tokens: 0,
        ok: 0,
        total: results.len(),
        steps: state
            .metrics
            .sched_steps_total
            .load(std::sync::atomic::Ordering::Relaxed)
            .saturating_sub(steps0),
        all: Vec::new(),
        short: Vec::new(),
    };
    for (lat, gen_len, resp) in &results {
        match resp {
            Ok((200, body)) => {
                stats.ok += 1;
                stats.all.push(*lat);
                if *gen_len == SHORT_GEN {
                    stats.short.push(*lat);
                }
                let j = parse(body).unwrap();
                stats.tokens += j.get("tokens").as_usize().unwrap_or(0);
            }
            other => println!("[{label}] request failed: {other:?}"),
        }
    }
    let (_, metrics_body) = http_get(&addr, "/metrics")?;
    println!("[{label}] server metrics: {metrics_body}");
    server.stop();
    state.scheduler.shutdown();
    Ok(stats)
}

/// Minimal well-behaved client for the 429 path: on backpressure, honor the
/// refusal's `retry_after_ms` hint plus additive jitter (derived from the
/// clock's subsecond nanos — no rand crate) instead of hammering the pool.
/// Returns the terminal response and how many backoffs it took.
fn post_with_backoff(
    addr: &str,
    body: &str,
    max_attempts: usize,
) -> anyhow::Result<(u16, String, usize)> {
    let mut backoffs = 0usize;
    loop {
        let (code, resp) = http_post(addr, "/generate", body)?;
        if code != 429 || backoffs + 1 >= max_attempts {
            return Ok((code, resp, backoffs));
        }
        let hint_ms = parse(&resp)
            .ok()
            .and_then(|j| j.get("retry_after_ms").as_usize())
            .unwrap_or(100) as u64;
        let jitter_ms = u64::from(
            SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.subsec_nanos())
                .unwrap_or(0),
        ) % (hint_ms / 2 + 1);
        backoffs += 1;
        std::thread::sleep(Duration::from_millis(hint_ms + jitter_ms));
    }
}

/// (p50, p95), tolerating an empty sample set (all requests failed).
fn pctls(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        (f64::NAN, f64::NAN)
    } else {
        let s = Summary::of(xs);
        (s.p50, s.p95)
    }
}

fn print_phase(s: &PhaseStats) {
    let agg = s.tokens as f64 / s.wall.max(1e-9);
    let (p50, p95) = pctls(&s.all);
    let (_, short_p95) = pctls(&s.short);
    println!(
        "{:<22} {:>2}/{:<2} ok  wall={:>6.2}s  agg={:>7.1} tok/s  \
         p50={p50:.2}s p95={p95:.2}s  short-p95={short_p95:.2}s",
        s.label, s.ok, s.total, s.wall, agg
    );
}

fn main() -> anyhow::Result<()> {
    let n_requests: usize =
        std::env::var("WD_REQS").ok().and_then(|v| v.parse().ok()).unwrap_or(8);
    let concurrency: usize =
        std::env::var("WD_CONC").ok().and_then(|v| v.parse().ok()).unwrap_or(8);

    // -- boot one shared executor (sim model, or mock without artifacts) -------
    let (exec, tok, prompts, model_name, manifest): (
        Arc<dyn StepExec + Send + Sync>,
        Tokenizer,
        Vec<String>,
        &'static str,
        Option<Manifest>,
    ) = match Manifest::load(&Manifest::default_root()) {
        Ok(manifest) => {
            let engine = Engine::load(&manifest, "dream-sim-instruct")?;
            let tok = Tokenizer::load(&manifest.vocab_file)?;
            let mut prompts = Vec::new();
            for (i, task) in ["synth-gsm", "synth-mbpp", "synth-he", "synth-math"]
                .iter()
                .cycle()
                .take(n_requests)
                .enumerate()
            {
                let instances = eval::load_task(&manifest.tasks_dir, task, "instruct")?;
                prompts.push(instances[i % instances.len()].prompt.clone());
            }
            let exec: Arc<dyn StepExec + Send + Sync> = EngineCell::new(engine);
            (exec, tok, prompts, "dream-sim-instruct", Some(manifest))
        }
        Err(e) => {
            eprintln!("[serve_batch] artifacts unavailable ({e}); using the mock model");
            let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
            (exec, toy_tokenizer(), vec!["w1 w2 w3 w4".to_string(); n_requests], "mock", None)
        }
    };

    // -- mixed workload: alternating short/long, window + full strategies ------
    let bodies: Vec<(String, usize)> = prompts
        .iter()
        .enumerate()
        .map(|(i, prompt)| {
            let gen_len = if i % 2 == 0 { SHORT_GEN } else { LONG_GEN };
            let body = Json::obj(vec![
                ("prompt", Json::str(prompt.clone())),
                ("gen_len", Json::num(gen_len as f64)),
                ("strategy", Json::str(if i % 4 == 3 { "full" } else { "window" })),
                ("adaptive", Json::Bool(false)),
            ]);
            (body.to_string(), gen_len)
        })
        .collect();

    println!(
        "=== serve_batch: {n_requests} requests ({SHORT_GEN}/{LONG_GEN} tok mixed), \
         concurrency={concurrency}, model={model_name} ==="
    );

    // -- phase 1: legacy worker-per-request ------------------------------------
    let direct = run_phase(
        "worker-per-request",
        build_state(Arc::clone(&exec), None, tok.clone(), model_name,
                    SchedulerConfig::default(), 1, true),
        &bodies,
        concurrency,
    )?;

    // -- phase 2: step-level scheduler (round-robin) ---------------------------
    // ring tracing on: the mid-flight /sessions probe shows recorder-sourced
    // queue_ms/ttft_ms next to the derived age/busy columns
    let sched = run_phase(
        "scheduler[rr]",
        build_state(
            Arc::clone(&exec),
            None,
            tok.clone(),
            model_name,
            SchedulerConfig {
                policy: Policy::RoundRobin,
                trace: TraceMode::Ring,
                ..Default::default()
            },
            1,
            false,
        ),
        &bodies,
        concurrency,
    )?;

    println!("\n--- comparison ---");
    print_phase(&direct);
    print_phase(&sched);
    let agg_d = direct.tokens as f64 / direct.wall.max(1e-9);
    let agg_s = sched.tokens as f64 / sched.wall.max(1e-9);
    println!(
        "scheduler/worker aggregate throughput: {:.2}x, short-p95: {:.2}s -> {:.2}s",
        agg_s / agg_d.max(1e-9),
        pctls(&direct.short).1,
        pctls(&sched.short).1,
    );

    // -- phase 3: engine-replica pool — 1 vs N replicas+drivers ----------------
    // same scheduler workload; the only variable is the replica count (and
    // one driver worker per replica). On the mock path each step costs an
    // artificial 1 ms so the speedup is measurable anywhere.
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let n_replicas: usize = std::env::var("WD_REPLICAS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
        .clamp(1, hw.max(1));
    // mock pools share ONE in-memory weight bank across replicas, exactly
    // like `EnginePool::load`'s default shared mode over real artifacts —
    // the bank gauges below report host residency either way
    let mock_bank = Arc::new(window_diffusion::runtime::WeightBank::from_host_params(
        "mock",
        vec![window_diffusion::runtime::HostParam {
            name: "embed".into(),
            shape: vec![64, 16],
            data: vec![0.01; 1024],
        }],
    ));
    let make_pool = |k: usize| -> anyhow::Result<Arc<EnginePool>> {
        match &manifest {
            Some(m) => EnginePool::load(m, "dream-sim-instruct", k),
            None => EnginePool::new(
                (0..k)
                    .map(|_| {
                        Arc::new(
                            MockExec::new(256)
                                .with_step_delay(Duration::from_millis(1))
                                .with_weight_bank(Arc::clone(&mock_bank)),
                        ) as Arc<dyn StepExec + Send + Sync>
                    })
                    .collect(),
            ),
        }
    };
    if n_replicas == 1 {
        println!(
            "\n--- replica scaling skipped (WD_REPLICAS/available_parallelism \
             clamp to 1; nothing to compare) ---"
        );
    } else {
        let mut pool_phases = Vec::new();
        for k in [1usize, n_replicas] {
            let pool = make_pool(k)?;
            println!(
                "pool[{k} replicas]: weight bank {} — {} host bytes total, \
                 {} per replica upload",
                pool.bank_mode(),
                pool.weight_bytes_host(),
                pool.weight_bytes_per_replica(),
            );
            let exec_k: Arc<dyn StepExec + Send + Sync> = Arc::clone(&pool);
            let st = build_state(
                exec_k,
                Some(pool),
                tok.clone(),
                model_name,
                SchedulerConfig::default(),
                k,
                false,
            );
            let label = format!("pool[{k} replicas]");
            pool_phases.push(run_phase(&label, st, &bodies, concurrency)?);
        }
        println!("\n--- replica scaling ---");
        for p in &pool_phases {
            print_phase(p);
        }
        let sp1 = pool_phases[0].steps_per_sec();
        let spn = pool_phases[1].steps_per_sec();
        println!(
            "{n_replicas}-replica vs 1-replica: {:.1} -> {:.1} steps/sec ({:.2}x)",
            sp1,
            spn,
            spn / sp1.max(1e-9),
        );
    }

    // -- phase 4: cross-session micro-batching — max_batch ∈ {1, 4, 8} ---------
    // same scheduler workload, one driver; the only variable is the
    // coalescing width B. On the mock path each forward costs 1 ms and the
    // batched mock pays it once per batch, so steps/sec should scale with
    // occupancy; with artifacts the engine batches when the manifest ships
    // batched executables (b_ladder) and falls back to solo loops otherwise.
    let make_batch_exec = || -> anyhow::Result<Arc<dyn StepExec + Send + Sync>> {
        let exec: Arc<dyn StepExec + Send + Sync> = match &manifest {
            Some(m) => EngineCell::new(Engine::load(m, "dream-sim-instruct")?),
            None => Arc::new(MockExec::new(256).with_step_delay(Duration::from_millis(1))),
        };
        Ok(exec)
    };
    let mut batch_phases: Vec<(usize, PhaseStats, f64)> = Vec::new();
    for b in [1usize, 4, 8] {
        let exec_b = make_batch_exec()?;
        let st = build_state(
            exec_b,
            None,
            tok.clone(),
            model_name,
            SchedulerConfig { max_batch: b, ..Default::default() },
            1,
            false,
        );
        let metrics_b = Arc::clone(&st.metrics);
        let label = format!("batch[B={b}]");
        let phase = run_phase(&label, st, &bodies, concurrency)?;
        batch_phases.push((b, phase, metrics_b.batch_occupancy()));
    }
    println!("\n--- micro-batch scaling (1 driver, coalesced forwards) ---");
    for (b, p, occ) in &batch_phases {
        print_phase(p);
        println!(
            "  B={b}: {:.1} steps/sec, batch_occupancy={occ:.2}",
            p.steps_per_sec()
        );
    }
    let sp1 = batch_phases[0].1.steps_per_sec();
    let spb = batch_phases.last().map(|(_, p, _)| p.steps_per_sec()).unwrap_or(sp1);
    println!(
        "B=8 vs B=1: {:.1} -> {:.1} steps/sec ({:.2}x)",
        sp1,
        spb,
        spb / sp1.max(1e-9),
    );

    // -- phase 5: load-adaptive + cross-bucket coalescing A/B ------------------
    // a deliberately heterogeneous workload: two window geometries that land
    // on DIFFERENT c buckets (w64 at gen 96 needs c=128, w16 fits c=64) plus
    // full-strategy sessions. Exact-bucket coalescing (fixed B) mostly fails
    // to pair lanes here; the adaptive governor + cross-bucket promotion
    // (--coalesce-waste-pct) is what fills forwards back up.
    let hetero_bodies: Vec<(String, usize)> = prompts
        .iter()
        .enumerate()
        .map(|(i, prompt)| {
            let (strategy, gen_len) = match i % 4 {
                0 => ("window:w_ex=64,a=16", LONG_GEN),
                1 => ("window:w_ex=16,a=4", LONG_GEN),
                2 => ("full", SHORT_GEN),
                _ => ("window:w_ex=16,a=4", SHORT_GEN),
            };
            let body = Json::obj(vec![
                ("prompt", Json::str(prompt.clone())),
                ("gen_len", Json::num(gen_len as f64)),
                ("strategy", Json::str(strategy)),
                ("adaptive", Json::Bool(false)),
            ]);
            (body.to_string(), gen_len)
        })
        .collect();
    let coalesce_cfgs: [(&str, SchedulerConfig); 3] = [
        ("hetero[fixed B=1]", SchedulerConfig { max_batch: 1, ..Default::default() }),
        ("hetero[fixed B=8]", SchedulerConfig { max_batch: 8, ..Default::default() }),
        (
            "hetero[adaptive]",
            SchedulerConfig {
                max_batch: 8,
                batch_policy: BatchPolicy::Adaptive,
                coalesce_waste_pct: 50,
                ..Default::default()
            },
        ),
    ];
    let mut hetero_phases: Vec<(PhaseStats, f64, u64)> = Vec::new();
    for (label, cfg) in coalesce_cfgs {
        let exec_b = make_batch_exec()?;
        let st = build_state(exec_b, None, tok.clone(), model_name, cfg, 1, false);
        let metrics_b = Arc::clone(&st.metrics);
        let phase = run_phase(label, st, &hetero_bodies, concurrency)?;
        hetero_phases.push((
            phase,
            metrics_b.batch_occupancy(),
            metrics_b
                .promoted_lanes
                .load(std::sync::atomic::Ordering::Relaxed),
        ));
    }
    println!("\n--- load-adaptive coalescing (heterogeneous buckets, 1 driver) ---");
    for (p, occ, promoted) in &hetero_phases {
        print_phase(p);
        println!(
            "  {}: {:.1} steps/sec, batch_occupancy={occ:.2}, promoted_lanes={promoted}",
            p.label,
            p.steps_per_sec()
        );
    }
    let (solo_sps, fixed8_occ, adaptive_sps, adaptive_occ) = (
        hetero_phases[0].0.steps_per_sec(),
        hetero_phases[1].1,
        hetero_phases[2].0.steps_per_sec(),
        hetero_phases[2].1,
    );
    println!(
        "adaptive vs fixed B=1: {solo_sps:.1} -> {adaptive_sps:.1} steps/sec ({:.2}x); \
         occupancy vs fixed B=8: {fixed8_occ:.2} -> {adaptive_occ:.2}",
        adaptive_sps / solo_sps.max(1e-9),
    );

    // -- KV-pool admission control: tiny budget answers 429 --------------------
    let tiny = build_state(
        Arc::clone(&exec),
        None,
        tok.clone(),
        model_name,
        SchedulerConfig { kv_budget_bytes: 1024, ..Default::default() },
        1,
        false,
    );
    let server = serve(
        Arc::clone(&tiny),
        ServerConfig { addr: "127.0.0.1:0".into(), workers: 2, queue_capacity: 8 },
    )?;
    let (code, body) = http_post(&server.addr, "/generate", &bodies[0].0)?;
    // the refusal carries a machine-readable backoff hint (ISSUE 7): derived
    // from the trailing byte free rate, so clients retry when bytes could
    // plausibly be free instead of hammering a wedged pool
    let retry_ms = window_diffusion::util::json::parse(&body)
        .ok()
        .and_then(|j| j.get("retry_after_ms").as_usize());
    println!(
        "\nkv-pool admission with 1 KiB budget: HTTP {code} {}",
        match (code, retry_ms) {
            (429, Some(ms)) => format!("(rejected, as designed; retry_after_ms={ms})"),
            (429, None) => "(rejected, as designed — but retry_after_ms missing!)".into(),
            _ => body.clone(),
        }
    );
    server.stop();
    tiny.scheduler.shutdown();

    // -- a well-behaved 429 client: honor retry_after_ms until bytes free ------
    // budget = exactly one full-size KV bucket (mock arch), so a long session
    // books the whole pool; a second client is refused with a backoff hint
    // and retries with jitter until the holder completes. Mock-only (2 ms per
    // forward keeps the holder in flight long enough to observe the refusal).
    let demo_exec: Arc<dyn StepExec + Send + Sync> =
        Arc::new(MockExec::new(256).with_step_delay(Duration::from_millis(2)));
    let est_max = KvPool::estimate_bytes(&demo_exec.arch(), &demo_exec.c_ladder(256), 256);
    let gated = build_state(
        Arc::clone(&demo_exec),
        None,
        toy_tokenizer(),
        "mock",
        SchedulerConfig { kv_budget_bytes: est_max, ..Default::default() },
        1,
        false,
    );
    let server = serve(
        Arc::clone(&gated),
        ServerConfig { addr: "127.0.0.1:0".into(), workers: 4, queue_capacity: 8 },
    )?;
    let gated_addr = server.addr.clone();
    let mk_body = |gen_len: usize, strategy: &str| {
        Json::obj(vec![
            ("prompt", Json::str("w1 w2 w3 w4")),
            ("gen_len", Json::num(gen_len as f64)),
            ("strategy", Json::str(strategy)),
            ("adaptive", Json::Bool(false)),
        ])
        .to_string()
    };
    let holder_addr = gated_addr.clone();
    let holder_body = mk_body(200, "full"); // books the largest c bucket
    let holder = std::thread::spawn(move || http_post(&holder_addr, "/generate", &holder_body));
    std::thread::sleep(Duration::from_millis(40)); // let the holder reserve
    let (code, _resp, backoffs) =
        post_with_backoff(&gated_addr, &mk_body(SHORT_GEN, "window"), 50)?;
    println!(
        "429-aware client vs one-bucket budget: HTTP {code} after {backoffs} jittered backoff(s)"
    );
    assert_eq!(code, 200, "backoff client never got admitted");
    let _ = holder.join();
    server.stop();
    gated.scheduler.shutdown();

    // -- chaos drill: ~10% transient forward faults, retry-with-replan ---------
    // the mixed workload through a chaos-wrapped 2-replica mock pool; every
    // request must still answer 200 — faults surface only as booked retries
    // (and quarantines, were any replica to fail persistently)
    let chaos = ChaosPlan::new(ChaosConfig { transient_per_mille: 100, ..Default::default() });
    let chaos_pool = EnginePool::new(
        (0..2usize)
            .map(|i| {
                let inner: Arc<dyn StepExec + Send + Sync> =
                    Arc::new(MockExec::new(256).with_step_delay(Duration::from_millis(1)));
                Arc::new(chaos.wrap(i as u32, inner)) as Arc<dyn StepExec + Send + Sync>
            })
            .collect(),
    )?;
    chaos_pool.configure_health(3, 250);
    let chaos_exec: Arc<dyn StepExec + Send + Sync> = Arc::clone(&chaos_pool);
    let chaos_state = build_state(
        chaos_exec,
        Some(Arc::clone(&chaos_pool)),
        toy_tokenizer(),
        "mock",
        SchedulerConfig { max_step_retries: 6, ..Default::default() },
        2,
        false,
    );
    let chaos_bodies: Vec<(String, usize)> = (0..n_requests)
        .map(|i| {
            let gen_len = if i % 2 == 0 { SHORT_GEN } else { LONG_GEN };
            (mk_body(gen_len, if i % 4 == 3 { "full" } else { "window" }), gen_len)
        })
        .collect();
    let chaos_phase =
        run_phase("chaos[10% transient]", Arc::clone(&chaos_state), &chaos_bodies, concurrency)?;
    println!("\n--- chaos drill (2 mock replicas, 10% transient faults) ---");
    print_phase(&chaos_phase);
    let c = chaos.counters();
    println!(
        "  injected: transient={} persistent={} stuck={} upload_failures={}",
        c.transient(),
        c.persistent(),
        c.stuck(),
        c.upload_failures()
    );
    println!(
        "  recovered: step_retries={} exhausted={} quarantines={}",
        chaos_state
            .metrics
            .step_retries
            .load(std::sync::atomic::Ordering::Relaxed),
        chaos_state
            .metrics
            .step_retries_exhausted
            .load(std::sync::atomic::Ordering::Relaxed),
        chaos_pool.quarantines(),
    );
    assert_eq!(
        chaos_phase.ok, chaos_phase.total,
        "chaos drill dropped requests — transient faults must not surface to clients"
    );
    Ok(())
}
