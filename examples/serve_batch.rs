//! End-to-end serving driver (DESIGN.md: the E2E validation example).
//!
//! Boots the full serving stack on a trained sim model, fires concurrent
//! batched requests from client threads (mixed task types and strategies),
//! and reports latency percentiles + aggregate throughput — the
//! "load a small real model and serve batched requests" proof that all
//! three layers compose. Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_batch
//! ```

use std::sync::Arc;
use std::time::Instant;

use window_diffusion::eval;
use window_diffusion::metrics::Metrics;
use window_diffusion::runtime::{Engine, EngineCell, Manifest};
use window_diffusion::server::api::AppState;
use window_diffusion::server::http::{http_get, http_post};
use window_diffusion::server::{serve, ServerConfig};
use window_diffusion::tokenizer::Tokenizer;
use window_diffusion::util::json::{parse, Json};
use window_diffusion::util::stats::Summary;
use window_diffusion::util::threadpool::parallel_map;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::var("WD_REQS").ok().and_then(|v| v.parse().ok()).unwrap_or(12);
    let concurrency: usize = std::env::var("WD_CONC").ok().and_then(|v| v.parse().ok()).unwrap_or(4);

    // -- boot the serving stack ------------------------------------------------
    let manifest = Manifest::load(&Manifest::default_root())?;
    let engine = Engine::load(&manifest, "dream-sim-instruct")?;
    let tok = Tokenizer::load(&manifest.vocab_file)?;
    let state = Arc::new(AppState {
        engine: EngineCell::new(engine),
        tokenizer: tok,
        metrics: Arc::new(Metrics::default()),
        model_name: "dream-sim-instruct".into(),
        default_strategy: "window".into(),
        default_gen_len: 64,
        s: 256,
    });
    let server = serve(
        state.clone(),
        ServerConfig { addr: "127.0.0.1:0".into(), workers: concurrency, queue_capacity: 64 },
    )?;
    let addr = server.addr.clone();
    println!("serving dream-sim-instruct on http://{addr}");

    // -- build a mixed workload from the held-out suites -----------------------
    let mut bodies = Vec::new();
    for (i, task) in ["synth-gsm", "synth-mbpp", "synth-he", "synth-math"].iter().cycle()
        .take(n_requests).enumerate()
    {
        let instances = eval::load_task(&manifest.tasks_dir, task, "instruct")?;
        let inst = &instances[i % instances.len()];
        let body = Json::obj(vec![
            ("prompt", Json::str(inst.prompt.clone())),
            ("gen_len", Json::num(64.0)),
            ("strategy", Json::str(if i % 4 == 3 { "full" } else { "window" })),
            ("adaptive", Json::Bool(true)),
        ]);
        bodies.push(body.to_string());
    }

    // warmup (compile all buckets once)
    let _ = http_post(&addr, "/generate", &bodies[0]);

    // -- fire concurrently -------------------------------------------------------
    let t0 = Instant::now();
    let addr2 = addr.clone();
    let results = parallel_map(bodies, concurrency, move |body| {
        let t = Instant::now();
        let r = http_post(&addr2, "/generate", &body);
        (t.elapsed().as_secs_f64(), r)
    });
    let wall = t0.elapsed().as_secs_f64();

    // -- report -------------------------------------------------------------------
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    let mut ok = 0usize;
    for (lat, resp) in &results {
        match resp {
            Ok((200, body)) => {
                ok += 1;
                latencies.push(*lat);
                let j = parse(body).unwrap();
                tokens += j.get("tokens").as_usize().unwrap_or(0);
            }
            other => println!("request failed: {other:?}"),
        }
    }
    let s = Summary::of(&latencies);
    println!("\n=== serve_batch: {ok}/{} ok, concurrency={concurrency} ===", results.len());
    println!("wall = {wall:.2}s   aggregate throughput = {:.1} tok/s", tokens as f64 / wall);
    println!("latency p50 = {:.2}s  p95 = {:.2}s  max = {:.2}s", s.p50, s.p95, s.max);

    let (_, metrics_body) = http_get(&addr, "/metrics")?;
    println!("server metrics: {metrics_body}");
    server.stop();
    Ok(())
}
